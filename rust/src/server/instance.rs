//! One inference-server instance — a Triton pod bound to one (simulated)
//! GPU.
//!
//! The executor is a single thread that pops dynamic batches from the
//! instance's [`BatchQueue`] and runs them on the shared PJRT engines.
//! Serializing execution per instance is the GPU model: one kernel stream,
//! requests queue behind each other, and "GPU utilization" is the busy-time
//! fraction — exactly the quantity Fig. 3 plots. The real compute happens
//! on the CPU through XLA, so latency numbers are real end-to-end numbers.
//!
//! State machine: `Starting -> Ready -> Draining -> Stopped`. The gateway
//! only routes to `Ready` instances; the orchestrator drives transitions.
//!
//! Per-model serving state (the warm-load cost model): each entry in the
//! serving set is either **`Loading`** — the simulated model-load window
//! after a placement `load_model`, during which the model consumes GPU
//! memory but is *not* advertised (routers exclude it from address
//! pools, `submit` sheds its requests as `Overloaded`) — or **warm**,
//! once the model's configured `load_delay` has elapsed. Bootstrap
//! placements ([`Instance::set_loaded_models`]) skip the window: the
//! pod's `startup_delay` already charges the initial load.
//!
//! **Backends.** Every serving-set entry also records which
//! [`Backend`](crate::engine::Backend) serves the model here: the first
//! entry of the model's preference list
//! ([`EngineCatalog`](crate::engine::EngineCatalog)) that this
//! instance's backend set supports. A model with no compatible backend
//! cannot enter the serving set at all (`load_model` returns false,
//! bootstrap skips it), the chosen backend's multipliers scale the
//! model's warm-load delay and memory footprint, and the executor
//! dispatches every batch through it. Picking any backend past the
//! first preference is a *fallback*, counted in
//! `backend_fallback_total`.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::Duration;

use crate::config::{BatchMode, ExecutionMode, ModelConfig, ServiceModelConfig};
use crate::engine::{AcceleratorClass, Backend, BackendRegistry, EngineCatalog, ExecCtx};
use crate::metrics::registry::{labels, Registry};
use crate::rpc::codec::{Priority, Status};
use crate::runtime::Tensor;
use crate::server::batcher::{BatchPolicy, BatchQueue, ExecOutcome, Pending};
use crate::server::repository::ModelRepository;
use crate::telemetry::{Span, Tracer};
use crate::util::clock::{Clock, Nanos};

/// Instance lifecycle state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InstanceState {
    /// Pod scheduled, container starting / model loading.
    Starting = 0,
    /// Serving traffic.
    Ready = 1,
    /// No new work accepted; queue draining.
    Draining = 2,
    /// Executor joined.
    Stopped = 3,
}

impl InstanceState {
    fn from_u8(v: u8) -> Self {
        match v {
            0 => InstanceState::Starting,
            1 => InstanceState::Ready,
            2 => InstanceState::Draining,
            _ => InstanceState::Stopped,
        }
    }
}

/// Utilization accounting: busy intervals over a sliding window.
struct UtilWindow {
    /// (end_clock_secs, busy_secs) per completed batch.
    intervals: Vec<(f64, f64)>,
    window: f64,
}

impl UtilWindow {
    fn new(window: f64) -> Self {
        UtilWindow { intervals: Vec::new(), window }
    }

    fn record(&mut self, end: f64, busy: f64) {
        self.intervals.push((end, busy));
        let horizon = end - self.window;
        self.intervals.retain(|&(t, _)| t >= horizon);
    }

    fn utilization(&mut self, now: f64) -> f64 {
        let horizon = now - self.window;
        self.intervals.retain(|&(t, _)| t >= horizon);
        let busy: f64 = self.intervals.iter().map(|&(_, b)| b).sum();
        (busy / self.window).min(1.0)
    }
}

/// One simulated GPU server.
pub struct Instance {
    /// Stable id, e.g. "triton-3".
    pub id: String,
    queue: Arc<BatchQueue>,
    state: AtomicU8,
    inflight: AtomicUsize,
    repo: Arc<ModelRepository>,
    clock: Clock,
    util: Mutex<UtilWindow>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Remote-dispatch endpoint: the sonic-rpc server started by
    /// [`Instance::serve_rpc`] (None when dispatch is in-process).
    rpc: Mutex<Option<crate::rpc::RpcServer>>,
    /// Advertised rpc address — what the gateway's session pool dials.
    /// Kept separate from `rpc` so tests can point it at a hung listener.
    rpc_addr: RwLock<Option<String>>,
    // metrics handles
    m_requests: Mutex<HashMap<String, crate::metrics::registry::Counter>>,
    m_rows: crate::metrics::registry::Counter,
    m_batches: crate::metrics::registry::Counter,
    m_queue_hist: crate::metrics::registry::HistogramHandle,
    m_compute_hist: crate::metrics::registry::HistogramHandle,
    m_util: crate::metrics::registry::Gauge,
    m_queue_latency: crate::metrics::registry::Gauge,
    m_queue_depth: crate::metrics::registry::Gauge,
    m_busy_total: crate::metrics::registry::Gauge,
    registry: Registry,
    policies: HashMap<String, BatchPolicy>,
    exec_mode: ExecutionMode,
    service_models: HashMap<String, ServiceModelConfig>,
    /// The serving set: model -> serving entry (warm-at clock-nanos +
    /// the backend chosen for it). An entry with `warm_at` in the
    /// future is `Loading`: memory is already charged, but the model is
    /// not advertised (the Kubernetes pod-label mechanism from the
    /// dynamic-model-loading design: the per-model load balancers build
    /// their address pools from the *warm* entries only). The shared
    /// [`ModelRepository`] may hold more models; only advertised ones
    /// are accepted by [`Instance::submit`].
    loaded: RwLock<BTreeMap<String, Serving>>,
    /// The backend set this instance advertises (derived from its pod's
    /// accelerator class; never empty).
    backends: Vec<Arc<dyn Backend>>,
    /// Per-model backend preference lists (shared, deployment-wide).
    catalog: Arc<EngineCatalog>,
    /// Simulated warm-load window per model (clock time), from
    /// `ModelConfig::load_delay` (deployment-resolved; zero = instant).
    load_delays: HashMap<String, Duration>,
    /// True while any serving-set entry is still inside its warm-load
    /// window — lets the executor skip the per-wakeup gauge refresh in
    /// the (common) all-warm steady state. Maintained by
    /// `refresh_placement_gauges`, which runs one final time after the
    /// last window closes (the refresh that observes zero loading also
    /// clears the flag).
    loading_inflight: std::sync::atomic::AtomicBool,
    m_models_loaded: crate::metrics::registry::Gauge,
    m_models_loading: crate::metrics::registry::Gauge,
    m_memory_used: crate::metrics::registry::Gauge,
    /// Per-model queued-request gauges (the batcher backlog the
    /// placement demand signal consumes).
    m_queue_depth_model: HashMap<String, crate::metrics::registry::Gauge>,
    /// Per-priority queued-request gauges, indexed by
    /// [`Priority::index`].
    m_queue_depth_priority: [crate::metrics::registry::Gauge; Priority::COUNT],
    /// Per-priority shed counters (ingress rejections + shed-from-bulk
    /// evictions), indexed by [`Priority::index`].
    m_shed_priority: [crate::metrics::registry::Counter; Priority::COUNT],
    /// Higher-priority batches served past older lower-priority work.
    m_preemptions: crate::metrics::registry::Counter,
    /// Requests executed per backend (`backend_inference_total`), keyed
    /// by backend name.
    m_backend_inference: HashMap<&'static str, crate::metrics::registry::Counter>,
    /// Per-model fallback-selection counters (`backend_fallback_total`),
    /// created lazily like the per-model request counters.
    m_backend_fallback: Mutex<HashMap<String, crate::metrics::registry::Counter>>,
    /// Per-(model, priority) queue-wait histograms
    /// (`queue_wait_seconds{instance,model,priority}`), created lazily
    /// like the per-model request counters.
    m_queue_wait: Mutex<HashMap<(String, usize), crate::metrics::registry::HistogramHandle>>,
    /// Records server-side batch/compute spans (and shed terminal queue
    /// spans) for traced requests; disabled by default.
    tracer: Tracer,
}

/// One serving-set entry.
struct Serving {
    /// Clock-nanos at which the model is (or becomes) warm.
    warm_at: Nanos,
    /// The backend that serves this model on this instance.
    backend: Arc<dyn Backend>,
}

/// Tuning knobs for [`Instance::start_with_opts`] beyond the model list.
#[derive(Clone, Debug)]
pub struct InstanceOptions {
    /// Overload-shedding bound on the batch queue, in total queued
    /// rows (multi-row requests count their real weight).
    pub queue_capacity: usize,
    /// Utilization averaging window in clock seconds.
    pub util_window: f64,
    /// Real PJRT execution or calibrated simulated service times.
    pub exec_mode: ExecutionMode,
    /// Batch admission policy (`Affinity` default, `Fifo` baseline).
    pub batch_mode: BatchMode,
    /// Anti-starvation aging bound for the batcher's priority-first
    /// selection (`server.priorities.max_bulk_wait`; zero = off).
    pub max_bulk_wait: Duration,
    /// The backend set this instance advertises — its pod's accelerator
    /// class resolved through the
    /// [`BackendRegistry`](crate::engine::BackendRegistry). Must be
    /// non-empty; the default is the GPU-class set (PJRT only), which
    /// preserves the classic single-runtime behavior.
    pub backends: Vec<Arc<dyn Backend>>,
    /// Per-model backend preference lists. Leaving the default (empty)
    /// catalog makes the constructor resolve one from its model list,
    /// so `ModelConfig::backends` is honored either way; deployments
    /// pass the shared resolved catalog (which also carries the
    /// configured `engines.default_backend`).
    pub catalog: Arc<EngineCatalog>,
    /// Tracer shared with the gateway so server-side queue/batch/compute
    /// spans land on the propagated trace id (disabled by default).
    pub tracer: Tracer,
}

impl Default for InstanceOptions {
    fn default() -> Self {
        InstanceOptions {
            queue_capacity: 256,
            util_window: 10.0,
            exec_mode: ExecutionMode::Real,
            batch_mode: BatchMode::Affinity,
            max_bulk_wait: Duration::ZERO,
            backends: BackendRegistry::default().for_class(AcceleratorClass::Gpu),
            catalog: Arc::new(EngineCatalog::default()),
            tracer: Tracer::disabled(),
        }
    }
}

impl Instance {
    /// Create the instance (state `Starting`) and spawn its executor.
    ///
    /// `queue_capacity` is the overload-shedding bound; `util_window` the
    /// utilization averaging window in clock seconds.
    pub fn start(
        id: &str,
        repo: Arc<ModelRepository>,
        models: &[ModelConfig],
        clock: Clock,
        registry: Registry,
        queue_capacity: usize,
        util_window: f64,
    ) -> Arc<Self> {
        Self::start_with_mode(
            id,
            repo,
            models,
            clock,
            registry,
            queue_capacity,
            util_window,
            ExecutionMode::Real,
        )
    }

    /// [`Instance::start`] with an explicit execution mode (see
    /// `config::ExecutionMode`): `Simulated` sleeps the model's calibrated
    /// service time per batch instead of executing through PJRT.
    #[allow(clippy::too_many_arguments)]
    pub fn start_with_mode(
        id: &str,
        repo: Arc<ModelRepository>,
        models: &[ModelConfig],
        clock: Clock,
        registry: Registry,
        queue_capacity: usize,
        util_window: f64,
        exec_mode: ExecutionMode,
    ) -> Arc<Self> {
        Self::start_with_opts(
            id,
            repo,
            models,
            clock,
            registry,
            InstanceOptions { queue_capacity, util_window, exec_mode, ..Default::default() },
        )
    }

    /// Full-control constructor: [`Instance::start`] plus batch admission
    /// mode and execution mode via [`InstanceOptions`].
    pub fn start_with_opts(
        id: &str,
        repo: Arc<ModelRepository>,
        models: &[ModelConfig],
        clock: Clock,
        registry: Registry,
        opts: InstanceOptions,
    ) -> Arc<Self> {
        let policies: HashMap<String, BatchPolicy> = models
            .iter()
            .map(|m| {
                (
                    m.name.clone(),
                    BatchPolicy {
                        max_queue_delay: m.max_queue_delay,
                        preferred_rows: m.preferred_batch,
                        ..BatchPolicy::default() // max_rows set per-pop from the repo
                    },
                )
            })
            .collect();
        let service_models: HashMap<String, ServiceModelConfig> = models
            .iter()
            .map(|m| (m.name.clone(), m.service_model))
            .collect();
        let load_delays: HashMap<String, Duration> = models
            .iter()
            .map(|m| (m.name.clone(), m.load_delay.unwrap_or(Duration::ZERO)))
            .collect();
        let inst_labels = labels(&[("instance", id)]);
        let registry2 = registry.clone();
        let m_queue_depth_model: HashMap<String, crate::metrics::registry::Gauge> = models
            .iter()
            .map(|m| {
                (
                    m.name.clone(),
                    registry.gauge(
                        "model_queue_depth",
                        &labels(&[("instance", id), ("model", &m.name)]),
                    ),
                )
            })
            .collect();
        let prio_gauge = |p: &Priority| {
            registry2.gauge(
                "priority_queue_depth",
                &labels(&[("instance", id), ("priority", p.name())]),
            )
        };
        let prio_shed = |p: &Priority| {
            registry2.counter(
                "requests_shed_total",
                &labels(&[("instance", id), ("priority", p.name())]),
            )
        };
        let m_queue_depth_priority = [
            prio_gauge(&Priority::Bulk),
            prio_gauge(&Priority::Standard),
            prio_gauge(&Priority::Critical),
        ];
        let m_shed_priority = [
            prio_shed(&Priority::Bulk),
            prio_shed(&Priority::Standard),
            prio_shed(&Priority::Critical),
        ];
        assert!(!opts.backends.is_empty(), "instance needs at least one backend");
        // An unresolved (default, empty) catalog would treat every model
        // as unconstrained; resolve one from the model list instead so
        // per-model `backends` preferences are honored even when the
        // caller wired no catalog (deployments always pass a resolved
        // one, which also carries the `engines.default_backend` choice).
        let catalog = if opts.catalog.is_empty() {
            Arc::new(EngineCatalog::resolve(models, &crate::config::EnginesConfig::default()))
        } else {
            Arc::clone(&opts.catalog)
        };
        // Bootstrap serving set: every configured model this instance's
        // backend set can serve, warm immediately (the pod's
        // startup_delay already charged the initial load). Models with
        // no compatible backend are skipped — the modelmesh invariant
        // starts at birth.
        // (Fallback events are counted on placement operations —
        // `load_model` / `set_loaded_models` — not on this constructor
        // bootstrap, which the deployment factory immediately replaces.)
        let boot_serving: BTreeMap<String, Serving> = models
            .iter()
            .filter_map(|m| {
                catalog.select(&m.name, &opts.backends).map(|(backend, _)| {
                    (m.name.clone(), Serving { warm_at: 0, backend })
                })
            })
            .collect();
        let m_backend_inference: HashMap<&'static str, crate::metrics::registry::Counter> =
            opts.backends
                .iter()
                .map(|b| {
                    (
                        b.name(),
                        registry2.counter(
                            "backend_inference_total",
                            &labels(&[("instance", id), ("backend", b.name())]),
                        ),
                    )
                })
                .collect();
        let instance = Arc::new(Instance {
            id: id.to_string(),
            queue: Arc::new(
                BatchQueue::with_aging(
                    opts.queue_capacity,
                    opts.batch_mode,
                    opts.max_bulk_wait,
                )
                .with_tracer(opts.tracer.clone()),
            ),
            state: AtomicU8::new(InstanceState::Starting as u8),
            inflight: AtomicUsize::new(0),
            repo,
            clock: clock.clone(),
            util: Mutex::new(UtilWindow::new(opts.util_window)),
            handle: Mutex::new(None),
            rpc: Mutex::new(None),
            rpc_addr: RwLock::new(None),
            m_requests: Mutex::new(HashMap::new()),
            m_rows: registry.counter("inference_rows_total", &inst_labels),
            m_batches: registry.counter("inference_batches_total", &inst_labels),
            m_queue_hist: registry.histogram("request_queue_seconds", &inst_labels),
            m_compute_hist: registry.histogram("compute_seconds", &inst_labels),
            m_util: registry.gauge("gpu_utilization", &inst_labels),
            m_queue_latency: registry.gauge("queue_latency_seconds", &inst_labels),
            m_queue_depth: registry.gauge("queue_depth", &inst_labels),
            m_busy_total: registry.gauge("gpu_busy_seconds_total", &inst_labels),
            registry,
            policies,
            exec_mode: opts.exec_mode,
            service_models,
            loaded: RwLock::new(boot_serving),
            backends: opts.backends,
            catalog,
            load_delays,
            loading_inflight: std::sync::atomic::AtomicBool::new(false),
            m_models_loaded: registry2.gauge("models_loaded", &inst_labels),
            m_models_loading: registry2.gauge("models_loading", &inst_labels),
            m_memory_used: registry2.gauge("instance_memory_used_bytes", &inst_labels),
            m_queue_depth_model,
            m_queue_depth_priority,
            m_shed_priority,
            m_preemptions: registry2.counter("batch_preemptions_total", &inst_labels),
            m_backend_inference,
            m_backend_fallback: Mutex::new(HashMap::new()),
            m_queue_wait: Mutex::new(HashMap::new()),
            tracer: opts.tracer,
        });
        instance.refresh_placement_gauges();
        let exec = Arc::clone(&instance);
        let handle = std::thread::Builder::new()
            .name(format!("exec-{id}"))
            .spawn(move || exec.run())
            .expect("spawning executor");
        *instance.handle.lock().unwrap() = Some(handle);
        instance
    }

    /// Current state.
    pub fn state(&self) -> InstanceState {
        InstanceState::from_u8(self.state.load(Ordering::SeqCst))
    }

    /// Mark Ready (orchestrator calls after the simulated pod start delay).
    pub fn mark_ready(&self) {
        self.state
            .store(InstanceState::Ready as u8, Ordering::SeqCst);
    }

    /// Requests submitted but not yet answered.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Queue depth (requests waiting, not executing).
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Queued requests for one model — the per-(instance, model) backlog
    /// the placement controller folds into its demand signal.
    pub fn queue_depth_for(&self, model: &str) -> usize {
        self.queue.depth_for(model)
    }

    /// Queued requests for one model, split by priority class (indexed
    /// by [`Priority::index`]) — the priority-aware demand signal.
    pub fn queue_depth_prio_for(&self, model: &str) -> [usize; Priority::COUNT] {
        self.queue.priority_depth_for(model)
    }

    /// Utilization over the sliding window, as of now.
    pub fn utilization(&self) -> f64 {
        self.util.lock().unwrap().utilization(self.clock.now_secs())
    }

    /// Does this instance currently advertise `model` — present in the
    /// serving set AND warm? A model mid-load answers false: routers must
    /// not send it traffic yet.
    pub fn advertises(&self, model: &str) -> bool {
        let now = self.clock.now();
        self.loaded
            .read()
            .unwrap()
            .get(model)
            .is_some_and(|s| now >= s.warm_at)
    }

    /// Is `model` in the serving set but still inside its simulated
    /// warm-load window?
    pub fn is_loading(&self, model: &str) -> bool {
        let now = self.clock.now();
        self.loaded
            .read()
            .unwrap()
            .get(model)
            .is_some_and(|s| now < s.warm_at)
    }

    /// Currently advertised (warm) models, sorted. Models mid-load are
    /// excluded — this is the pool-membership view.
    pub fn loaded_models(&self) -> Vec<String> {
        let now = self.clock.now();
        self.loaded
            .read()
            .unwrap()
            .iter()
            .filter(|&(_, s)| now >= s.warm_at)
            .map(|(m, _)| m.clone())
            .collect()
    }

    /// Models currently inside their warm-load window, sorted.
    pub fn loading_models(&self) -> Vec<String> {
        let now = self.clock.now();
        self.loaded
            .read()
            .unwrap()
            .iter()
            .filter(|&(_, s)| now < s.warm_at)
            .map(|(m, _)| m.clone())
            .collect()
    }

    /// The whole serving set (warm and loading), sorted — the
    /// memory-occupancy view placement plans against.
    pub fn serving_set(&self) -> Vec<String> {
        self.loaded.read().unwrap().keys().cloned().collect()
    }

    /// Names of the backends this instance advertises (its pod's
    /// accelerator class resolved through the registry) — what the
    /// placement planner's compatibility filter consumes.
    pub fn backend_names(&self) -> Vec<String> {
        self.backends.iter().map(|b| b.name().to_string()).collect()
    }

    /// The backend serving `model` on this instance (None when the
    /// model is not in the serving set).
    pub fn backend_for_model(&self, model: &str) -> Option<String> {
        self.loaded
            .read()
            .unwrap()
            .get(model)
            .map(|s| s.backend.name().to_string())
    }

    /// Warm serving entries and their backend names, under ONE lock
    /// acquisition and ONE clock read — the per-(model, backend) gauge
    /// refresh snapshots each instance once instead of re-locking per
    /// (model, backend) pair.
    pub fn warm_backends(&self) -> BTreeMap<String, String> {
        let now = self.clock.now();
        self.loaded
            .read()
            .unwrap()
            .iter()
            .filter(|&(_, s)| now >= s.warm_at)
            .map(|(m, s)| (m.clone(), s.backend.name().to_string()))
            .collect()
    }

    /// Simulated memory one loaded copy of `model` costs on `backend`
    /// (the repository footprint scaled by the backend's multiplier).
    fn scaled_memory(&self, model: &str, backend: &dyn Backend) -> u64 {
        self.repo
            .get(model)
            .map(|e| (e.memory_bytes() as f64 * backend.memory_multiplier()).round() as u64)
            .unwrap_or(0)
    }

    /// Consistent placement snapshot: (warm models, loading models,
    /// memory used) under ONE lock acquisition and ONE clock read, so a
    /// model whose warm window expires mid-snapshot can never appear in
    /// neither set (which would make the planner see a floor violation
    /// that does not exist and plan a spurious repair load).
    pub fn placement_snapshot(&self) -> (Vec<String>, Vec<String>, u64) {
        let now = self.clock.now();
        let loaded = self.loaded.read().unwrap();
        let mut warm = Vec::new();
        let mut loading = Vec::new();
        let mut mem = 0u64;
        for (m, s) in loaded.iter() {
            if now >= s.warm_at {
                warm.push(m.clone());
            } else {
                loading.push(m.clone());
            }
            mem += self.scaled_memory(m, s.backend.as_ref());
        }
        (warm, loading, mem)
    }

    /// Replace the serving set wholesale, all entries warm immediately
    /// (placement bootstrap: the instance factory applies the initial
    /// placement before the pod is marked Ready, and the pod's
    /// `startup_delay` already charged the initial model load). Names
    /// absent from the repository — or with no backend this instance
    /// supports — are dropped.
    pub fn set_loaded_models(&self, names: &[String]) {
        {
            let mut loaded = self.loaded.write().unwrap();
            loaded.clear();
            for n in names {
                if self.repo.get(n).is_none() {
                    continue;
                }
                let Some((backend, rank)) = self.catalog.select(n, &self.backends) else {
                    continue;
                };
                if rank > 0 {
                    self.fallback_counter(n).inc();
                }
                loaded.insert(n.clone(), Serving { warm_at: 0, backend });
            }
        }
        self.refresh_placement_gauges();
    }

    /// Take a model into the serving set (Triton's explicit `load`
    /// model-control call at the instance level — the engines live in
    /// the shared repository, so "loading" is paying the model's memory
    /// on this GPU and waiting out its simulated load window). The model
    /// enters `Loading` for its configured `load_delay` scaled by the
    /// chosen backend's load multiplier (instantly warm when zero) and
    /// is advertised only once warm. The backend is the first entry of
    /// the model's preference list this instance supports; choosing any
    /// later entry counts a fallback. Returns false if the repository
    /// has no such model, no compatible backend exists here, or it was
    /// already in the serving set.
    pub fn load_model(&self, model: &str) -> bool {
        if self.repo.get(model).is_none() {
            return false;
        }
        let Some((backend, rank)) = self.catalog.select(model, &self.backends) else {
            return false;
        };
        let base = Self::model_cfg(&self.load_delays, model)
            .copied()
            .unwrap_or(Duration::ZERO);
        let delay = base.mul_f64(backend.load_multiplier());
        let warm_at = self.clock.now() + delay.as_nanos() as Nanos;
        let added = {
            use std::collections::btree_map::Entry;
            match self.loaded.write().unwrap().entry(model.to_string()) {
                Entry::Occupied(_) => false,
                Entry::Vacant(e) => {
                    e.insert(Serving { warm_at, backend });
                    true
                }
            }
        };
        if added {
            if rank > 0 {
                self.fallback_counter(model).inc();
            }
            self.refresh_placement_gauges();
        }
        added
    }

    /// Drop a model from the serving set (warm or mid-load — unloading a
    /// loading model cancels the load). Requests already queued for it
    /// are still served (the executor resolves engines through the
    /// shared repository), mirroring Triton's graceful unload. Returns
    /// false if the model was not in the serving set.
    pub fn unload_model(&self, model: &str) -> bool {
        let removed = self.loaded.write().unwrap().remove(model).is_some();
        if removed {
            self.refresh_placement_gauges();
        }
        removed
    }

    /// Simulated GPU memory consumed by the serving set, in bytes (each
    /// model costs [`ModelEntry::memory_bytes`](crate::server::ModelEntry::memory_bytes)
    /// scaled by its serving backend's memory multiplier). Loading
    /// models count: their memory is committed the moment the load
    /// starts.
    pub fn memory_used(&self) -> u64 {
        self.loaded
            .read()
            .unwrap()
            .iter()
            .map(|(m, s)| self.scaled_memory(m, s.backend.as_ref()))
            .sum()
    }

    fn refresh_placement_gauges(&self) {
        let now = self.clock.now();
        let (warm, loading, mem) = {
            let loaded = self.loaded.read().unwrap();
            let warm = loaded.values().filter(|s| now >= s.warm_at).count();
            let mem: u64 = loaded
                .iter()
                .map(|(m, s)| self.scaled_memory(m, s.backend.as_ref()))
                .sum();
            (warm, loaded.len() - warm, mem)
        };
        self.m_models_loaded.set(warm as f64);
        self.m_models_loading.set(loading as f64);
        self.m_memory_used.set(mem as f64);
        self.loading_inflight.store(loading > 0, Ordering::Relaxed);
    }

    /// [`Instance::submit_prio`] at the default `standard` priority.
    pub fn submit(
        self: &Arc<Self>,
        model: &str,
        input: Tensor,
        trace_id: u64,
    ) -> Result<mpsc::Receiver<ExecOutcome>, (Status, Tensor)> {
        self.submit_prio(model, input, Priority::Standard, trace_id)
    }

    /// Submit a request; returns a receiver for the outcome. On rejection
    /// the input tensor is handed back with the status so the caller can
    /// retry another instance without cloning (the gateway hot path).
    ///
    /// `priority` selects the batcher admission lane. When the queue is
    /// full, a higher-priority submit may evict queued lower-priority
    /// requests (shed-from-bulk) — the victims are answered `Overloaded`
    /// here, so their waiting gateway threads return immediately.
    pub fn submit_prio(
        self: &Arc<Self>,
        model: &str,
        input: Tensor,
        priority: Priority,
        trace_id: u64,
    ) -> Result<mpsc::Receiver<ExecOutcome>, (Status, Tensor)> {
        if self.state() != InstanceState::Ready {
            return Err((Status::Overloaded, input));
        }
        // Only advertised (warm) models are accepted — the modelmesh
        // invariant that a request never lands on an instance without
        // the model, even if the shared repository still holds its
        // engines. A model mid-load is a transient condition: shed as
        // Overloaded (retryable) rather than ModelNotFound.
        if !self.advertises(model) {
            let status = if self.is_loading(model) {
                Status::Overloaded
            } else {
                Status::ModelNotFound
            };
            return Err((status, input));
        }
        let entry = match self.repo.get(model) {
            Some(e) => e,
            None => return Err((Status::ModelNotFound, input)),
        };
        if entry.validate_input(input.shape()).is_err() {
            return Err((Status::BadRequest, input));
        }
        let (tx, rx) = mpsc::channel();
        let pending = Pending {
            model: model.to_string(),
            priority,
            input,
            enqueued: self.clock.now(),
            trace_id,
            reply: tx,
        };
        match self.queue.push(pending) {
            Ok(evicted) => {
                let shed_at = self.clock.now_secs();
                for victim in evicted {
                    self.m_shed_priority[victim.priority.index()].inc();
                    // Terminal queue span: the victim's wait ended in an
                    // eviction, not a pop — the trace still accounts for
                    // the time it spent queued.
                    self.tracer.record(Span {
                        trace_id: victim.trace_id,
                        name: "queue".into(),
                        start: victim.enqueued as f64 / 1e9,
                        end: shed_at,
                    });
                    let _ = victim.reply.send(ExecOutcome::Err {
                        status: Status::Overloaded,
                        message: format!(
                            "instance {} shed {} request for {}-priority admission",
                            self.id,
                            victim.priority.name(),
                            priority.name()
                        ),
                    });
                    self.inflight.fetch_sub(1, Ordering::SeqCst);
                }
                self.inflight.fetch_add(1, Ordering::SeqCst);
                Ok(rx)
            }
            Err(pending) => {
                self.m_shed_priority[priority.index()].inc();
                Err((Status::Overloaded, pending.input))
            }
        }
    }

    /// Submit and block for the outcome (gateway connection threads).
    pub fn submit_and_wait(
        self: &Arc<Self>,
        model: &str,
        input: Tensor,
        trace_id: u64,
    ) -> ExecOutcome {
        self.submit_and_wait_prio(model, input, Priority::Standard, trace_id)
    }

    /// [`Instance::submit_and_wait`] with an explicit priority class.
    pub fn submit_and_wait_prio(
        self: &Arc<Self>,
        model: &str,
        input: Tensor,
        priority: Priority,
        trace_id: u64,
    ) -> ExecOutcome {
        match self.submit_prio(model, input, priority, trace_id) {
            Ok(rx) => rx.recv().unwrap_or(ExecOutcome::Err {
                status: Status::Internal,
                message: "executor dropped request".into(),
            }),
            Err((status, _input)) => ExecOutcome::Err {
                status,
                message: format!("instance {} cannot accept work", self.id),
            },
        }
    }

    /// Begin draining; queue rejects new work.
    pub fn drain(&self) {
        self.state
            .store(InstanceState::Draining as u8, Ordering::SeqCst);
        self.queue.drain();
    }

    /// Drain and join the executor (and the rpc endpoint, if serving).
    pub fn stop(&self) {
        self.drain();
        if let Some(mut server) = self.rpc.lock().unwrap().take() {
            server.shutdown();
        }
        *self.rpc_addr.write().unwrap() = None;
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
        self.state
            .store(InstanceState::Stopped as u8, Ordering::SeqCst);
    }

    /// Expose this instance over sonic-rpc: the remote-dispatch path,
    /// where the gateway's session pool forwards routed requests to this
    /// endpoint over TCP instead of calling [`Instance::submit_prio`]
    /// in-process. Per-request metadata survives the hop: the propagated
    /// trace id (honoring the head-sampling bit) lands on the batcher's
    /// queue/batch/compute spans, and the explicit wire priority class
    /// picks the batcher lane (the gateway resolves priority defaults
    /// before forwarding, so an unset class falls back to `standard`).
    ///
    /// Returns the bound address (resolving `:0`), which is also
    /// advertised via [`Instance::rpc_addr`]. The endpoint stops with
    /// [`Instance::stop`].
    pub fn serve_rpc(
        self: &Arc<Self>,
        listen: &str,
        opts: crate::rpc::RpcServerOpts,
    ) -> anyhow::Result<std::net::SocketAddr> {
        use crate::rpc::codec::{InferRequest, InferResponse, RequestKind};
        // Weak handler: the server must not keep a stopped instance alive
        // (Instance owns the server — an Arc would be a cycle).
        let weak = Arc::downgrade(self);
        let handler: crate::rpc::server::Handler = Arc::new(move |req: InferRequest| {
            let Some(inst) = weak.upgrade() else {
                return InferResponse::err(
                    req.request_id,
                    Status::Overloaded,
                    "instance stopped",
                );
            };
            match req.kind {
                RequestKind::Health => {
                    if inst.state() == InstanceState::Ready {
                        InferResponse::ok(req.request_id, Tensor::zeros(vec![0]))
                    } else {
                        InferResponse::err(req.request_id, Status::Overloaded, "not ready")
                    }
                }
                RequestKind::Infer => {
                    let trace = if req.sampled { req.trace_id } else { 0 };
                    let priority = req.priority.unwrap_or_default();
                    match inst.submit_and_wait_prio(&req.model, req.input, priority, trace) {
                        ExecOutcome::Ok { output, queue_us, compute_us, batch_rows } => {
                            InferResponse {
                                status: Status::Ok,
                                request_id: req.request_id,
                                queue_us,
                                compute_us,
                                batch_size: batch_rows,
                                output,
                                error: String::new(),
                            }
                        }
                        ExecOutcome::Err { status, message } => {
                            InferResponse::err(req.request_id, status, message)
                        }
                    }
                }
            }
        });
        let server = crate::rpc::RpcServer::start_with_opts(listen, opts, handler)?;
        let addr = server.addr();
        *self.rpc_addr.write().unwrap() = Some(addr.to_string());
        *self.rpc.lock().unwrap() = Some(server);
        Ok(addr)
    }

    /// The advertised sonic-rpc endpoint (None = in-process dispatch).
    pub fn rpc_addr(&self) -> Option<String> {
        self.rpc_addr.read().unwrap().clone()
    }

    /// Test hook: advertise an arbitrary rpc endpoint (e.g. a listener
    /// that never answers, for io-timeout regressions) without starting
    /// a server.
    pub fn set_rpc_addr_for_test(&self, addr: &str) {
        *self.rpc_addr.write().unwrap() = Some(addr.to_string());
    }

    /// Per-model config lookup with version fallback: a versioned name
    /// (`base@vN`) not configured explicitly inherits the base model's
    /// entry — runtime-registered versions behave like their base until
    /// the deployment expands dedicated configs for them.
    fn model_cfg<'a, V>(map: &'a HashMap<String, V>, model: &str) -> Option<&'a V> {
        map.get(model).or_else(|| {
            let (base, version) = crate::server::split_version(model);
            version.and_then(|_| map.get(base))
        })
    }

    fn policy_for(&self, model: &str) -> BatchPolicy {
        let mut policy = Self::model_cfg(&self.policies, model).cloned().unwrap_or_default();
        // Cap batches at the model's largest compiled engine batch: folding
        // further only chains engine calls serially (see BatchPolicy docs).
        if let Some(entry) = self.repo.get(model) {
            policy.max_rows = entry.max_batch();
        }
        policy
    }

    fn requests_counter(&self, model: &str) -> crate::metrics::registry::Counter {
        let mut map = self.m_requests.lock().unwrap();
        map.entry(model.to_string())
            .or_insert_with(|| {
                self.registry.counter(
                    "inference_requests_total",
                    &labels(&[("instance", &self.id), ("model", model)]),
                )
            })
            .clone()
    }

    fn queue_wait_hist(
        &self,
        model: &str,
        priority: Priority,
    ) -> crate::metrics::registry::HistogramHandle {
        let mut map = self.m_queue_wait.lock().unwrap();
        map.entry((model.to_string(), priority.index()))
            .or_insert_with(|| {
                self.registry.histogram(
                    "queue_wait_seconds",
                    &labels(&[
                        ("instance", &self.id),
                        ("model", model),
                        ("priority", priority.name()),
                    ]),
                )
            })
            .clone()
    }

    fn fallback_counter(&self, model: &str) -> crate::metrics::registry::Counter {
        let mut map = self.m_backend_fallback.lock().unwrap();
        map.entry(model.to_string())
            .or_insert_with(|| {
                self.registry.counter(
                    "backend_fallback_total",
                    &labels(&[("instance", &self.id), ("model", model)]),
                )
            })
            .clone()
    }

    /// The backend a batch for `model` executes on: the serving entry's
    /// recorded backend, or — for a model unloaded mid-flight (graceful
    /// unload still serves queued work) — whatever the catalog would
    /// select here now. `None` is unreachable today (queued work implies
    /// the model was advertised, which implies a compatible backend);
    /// the executor answers it with an error rather than silently
    /// executing on an incompatible backend.
    fn backend_for(&self, model: &str) -> Option<Arc<dyn Backend>> {
        if let Some(s) = self.loaded.read().unwrap().get(model) {
            return Some(Arc::clone(&s.backend));
        }
        self.catalog.select(model, &self.backends).map(|(b, _)| b)
    }

    /// Executor loop.
    fn run(self: Arc<Self>) {
        let mut queue_lat_ewma = 0.0f64;
        let mut last_refresh = self.clock.now_secs();
        let mut last_preemptions = 0u64;
        loop {
            let batch = self.queue.pop_batch(
                &self.clock,
                |m| self.policy_for(m),
                Duration::from_millis(100),
            );
            // Refresh gauges on every wakeup (busy or idle).
            let now = self.clock.now_secs();
            let dt = (now - last_refresh).max(0.0);
            last_refresh = now;
            // Idle decay of the queue-latency signal (tau = 5 clock secs).
            queue_lat_ewma *= (-dt / 5.0).exp();
            self.m_util
                .set(self.util.lock().unwrap().utilization(now));
            self.m_queue_latency.set(queue_lat_ewma);
            self.m_queue_depth.set(self.queue.depth() as f64);
            // One lock acquisition for all per-model depths; models with
            // no queued work read as zero.
            let depths = self.queue.depths();
            for (model, gauge) in &self.m_queue_depth_model {
                let d = depths
                    .iter()
                    .find(|(m, _)| m == model)
                    .map(|&(_, d)| d)
                    .unwrap_or(0);
                gauge.set(d as f64);
            }
            // Per-priority lane depths + the preemption counter delta
            // (the batcher counts under its own lock; the executor
            // mirrors it into the registry).
            let prio_depths = self.queue.priority_depths();
            for (gauge, d) in self.m_queue_depth_priority.iter().zip(prio_depths) {
                gauge.set(d as f64);
            }
            let preemptions = self.queue.preemptions();
            if preemptions > last_preemptions {
                self.m_preemptions.add(preemptions - last_preemptions);
                last_preemptions = preemptions;
            }
            // Loading -> warm transitions are clock-driven (no event
            // fires), so the serving-set gauges need a refresh while a
            // load is in flight — plus one final pass when the last
            // window closes. Warm-only steady state skips the locks
            // entirely (loads/unloads refresh explicitly).
            if self.loading_inflight.load(Ordering::Relaxed) {
                self.refresh_placement_gauges();
            }

            let Some(batch) = batch else {
                if self.queue.drained() && self.state() != InstanceState::Ready {
                    return; // drained + draining => stop
                }
                continue;
            };

            let model = batch[0].model.clone();
            let entry = match self.repo.get(&model) {
                Some(e) => e,
                None => {
                    for p in batch {
                        let _ = p.reply.send(ExecOutcome::Err {
                            status: Status::ModelNotFound,
                            message: format!("model '{model}' unloaded"),
                        });
                        self.inflight.fetch_sub(1, Ordering::SeqCst);
                    }
                    continue;
                }
            };

            let total_rows: usize = batch.iter().map(|p| p.rows()).sum();
            let t_exec_start = self.clock.now();

            // Dispatch to the serving backend: stack requests, execute
            // (splitting over engine calls if a single request exceeds
            // the largest compiled batch). Never fall back to an
            // arbitrary backend — an unresolvable one (which queued work
            // should make impossible) fails the batch loudly instead of
            // quietly running a model where it must not run.
            let Some(backend) = self.backend_for(&model) else {
                debug_assert!(false, "queued batch for '{model}' with no backend");
                for p in batch {
                    let _ = p.reply.send(ExecOutcome::Err {
                        status: Status::Internal,
                        message: format!(
                            "instance {} has no compatible backend for '{model}'",
                            self.id
                        ),
                    });
                    self.inflight.fetch_sub(1, Ordering::SeqCst);
                }
                continue;
            };
            let result = {
                let inputs: Vec<&Tensor> = batch.iter().map(|p| &p.input).collect();
                let service = Self::model_cfg(&self.service_models, &model)
                    .copied()
                    .unwrap_or_default();
                backend.execute(&ExecCtx {
                    entry: entry.as_ref(),
                    inputs: &inputs,
                    total_rows,
                    mode: self.exec_mode,
                    service,
                    clock: &self.clock,
                })
            };
            let t_exec_end = self.clock.now();
            let compute_s = (t_exec_end - t_exec_start) as f64 / 1e9;
            let compute_us = (compute_s * 1e6) as u32;

            // Per-request stage telemetry: the (model, priority) queue
            // wait, plus batch-assembly and compute spans on the
            // propagated trace (the batcher already closed the "queue"
            // span at the pop).
            let t_exec_start_s = t_exec_start as f64 / 1e9;
            let t_exec_end_s = t_exec_end as f64 / 1e9;
            for p in &batch {
                let wait = (t_exec_start.saturating_sub(p.enqueued)) as f64 / 1e9;
                self.queue_wait_hist(&p.model, p.priority).observe(wait);
                if self.tracer.enabled() && p.trace_id != 0 {
                    self.tracer.record(Span {
                        trace_id: p.trace_id,
                        name: "batch".into(),
                        start: now,
                        end: t_exec_start_s,
                    });
                    self.tracer.record(Span {
                        trace_id: p.trace_id,
                        name: "compute".into(),
                        start: t_exec_start_s,
                        end: t_exec_end_s,
                    });
                }
            }

            // Account busy time + metrics.
            {
                let mut util = self.util.lock().unwrap();
                util.record(t_exec_end as f64 / 1e9, compute_s);
            }
            self.m_busy_total.add(compute_s);
            self.m_batches.inc();
            self.m_rows.add(total_rows as u64);
            self.m_compute_hist.observe(compute_s);
            self.requests_counter(&model).add(batch.len() as u64);
            if let Some(c) = self.m_backend_inference.get(backend.name()) {
                c.add(batch.len() as u64);
            }

            // Respond per request.
            match result {
                Ok(outputs) => {
                    for (p, output) in batch.into_iter().zip(outputs) {
                        let queue_s =
                            (t_exec_start.saturating_sub(p.enqueued)) as f64 / 1e9;
                        self.m_queue_hist.observe(queue_s);
                        // EWMA with alpha=0.2 drives the autoscaler signal.
                        queue_lat_ewma = 0.8 * queue_lat_ewma + 0.2 * queue_s;
                        let _ = p.reply.send(ExecOutcome::Ok {
                            output,
                            queue_us: (queue_s * 1e6) as u32,
                            compute_us,
                            batch_rows: total_rows as u32,
                        });
                        self.inflight.fetch_sub(1, Ordering::SeqCst);
                    }
                    self.m_queue_latency.set(queue_lat_ewma);
                }
                Err(e) => {
                    for p in batch {
                        let _ = p.reply.send(ExecOutcome::Err {
                            status: Status::Internal,
                            message: e.to_string(),
                        });
                        self.inflight.fetch_sub(1, Ordering::SeqCst);
                    }
                }
            }
        }
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::PjrtRuntime;
    use once_cell::sync::Lazy;

    static REPO: Lazy<Arc<ModelRepository>> = Lazy::new(|| {
        let rt = PjrtRuntime::cpu().unwrap();
        Arc::new(
            ModelRepository::load(
                &rt,
                std::path::Path::new("artifacts"),
                &["icecube_cnn".into()],
            )
            .unwrap(),
        )
    });

    /// Metadata-only repository for tests that never execute engines.
    static SIM_REPO: Lazy<Arc<ModelRepository>> = Lazy::new(|| {
        Arc::new(
            ModelRepository::load_metadata(
                std::path::Path::new("artifacts"),
                &["icecube_cnn".into()],
            )
            .unwrap(),
        )
    });

    fn test_instance(id: &str) -> Arc<Instance> {
        let models = vec![ModelConfig {
            name: "icecube_cnn".into(),
            max_queue_delay: Duration::from_millis(2),
            preferred_batch: 8,
            ..ModelConfig::default()
        }];
        let inst = Instance::start(
            id,
            Arc::clone(&REPO),
            &models,
            Clock::real(),
            Registry::new(),
            64,
            5.0,
        );
        inst.mark_ready();
        inst
    }

    fn sim_test_instance(id: &str) -> Arc<Instance> {
        let models = vec![ModelConfig {
            name: "icecube_cnn".into(),
            max_queue_delay: Duration::from_millis(1),
            preferred_batch: 8,
            service_model: ServiceModelConfig {
                base: Duration::from_millis(2),
                per_row: Duration::from_micros(100),
            },
            load_delay: None,
            backends: Vec::new(),
            ..ModelConfig::default()
        }];
        let inst = Instance::start_with_mode(
            id,
            Arc::clone(&SIM_REPO),
            &models,
            Clock::real(),
            Registry::new(),
            64,
            5.0,
            ExecutionMode::Simulated,
        );
        inst.mark_ready();
        inst
    }

    fn cnn_input(rows: usize) -> Tensor {
        Tensor::zeros(vec![rows, 16, 16, 3])
    }

    #[test]
    #[cfg_attr(
        not(feature = "pjrt"),
        ignore = "needs compiled PJRT engines: build with --features pjrt after `make artifacts`"
    )]
    fn serves_single_request() {
        let inst = test_instance("t0");
        let out = inst.submit_and_wait("icecube_cnn", cnn_input(1), 0);
        match out {
            ExecOutcome::Ok { output, batch_rows, .. } => {
                assert_eq!(output.shape(), &[1, 3]);
                assert!(batch_rows >= 1);
            }
            other => panic!("unexpected {other:?}"),
        }
        inst.stop();
    }

    #[test]
    #[cfg_attr(
        not(feature = "pjrt"),
        ignore = "needs compiled PJRT engines: build with --features pjrt after `make artifacts`"
    )]
    fn batches_concurrent_requests() {
        let inst = test_instance("t1");
        let mut rxs = Vec::new();
        for _ in 0..8 {
            rxs.push(inst.submit("icecube_cnn", cnn_input(1), 0).unwrap());
        }
        let mut max_batch = 0;
        for rx in rxs {
            match rx.recv().unwrap() {
                ExecOutcome::Ok { batch_rows, output, .. } => {
                    assert_eq!(output.shape(), &[1, 3]);
                    max_batch = max_batch.max(batch_rows);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // dynamic batching must have folded at least two requests together
        assert!(max_batch >= 2, "no batching observed (max {max_batch})");
        inst.stop();
    }

    #[test]
    #[cfg_attr(
        not(feature = "pjrt"),
        ignore = "needs compiled PJRT engines: build with --features pjrt after `make artifacts`"
    )]
    fn oversized_request_split_across_engines() {
        let inst = test_instance("t2");
        // 40 rows > max compiled batch (16): executor must chunk.
        let out = inst.submit_and_wait("icecube_cnn", cnn_input(40), 0);
        match out {
            ExecOutcome::Ok { output, .. } => assert_eq!(output.shape(), &[40, 3]),
            other => panic!("unexpected {other:?}"),
        }
        inst.stop();
    }

    #[test]
    fn unknown_model_rejected() {
        let inst = sim_test_instance("t3");
        match inst.submit_and_wait("nope", cnn_input(1), 0) {
            ExecOutcome::Err { status, .. } => assert_eq!(status, Status::ModelNotFound),
            other => panic!("unexpected {other:?}"),
        }
        inst.stop();
    }

    #[test]
    fn bad_shape_rejected() {
        let inst = sim_test_instance("t4");
        let bad = Tensor::zeros(vec![1, 8, 8, 3]);
        match inst.submit_and_wait("icecube_cnn", bad, 0) {
            ExecOutcome::Err { status, .. } => assert_eq!(status, Status::BadRequest),
            other => panic!("unexpected {other:?}"),
        }
        inst.stop();
    }

    #[test]
    fn starting_instance_rejects() {
        let models = vec![ModelConfig { name: "icecube_cnn".into(), ..ModelConfig::default() }];
        let inst = Instance::start_with_mode(
            "t5",
            Arc::clone(&SIM_REPO),
            &models,
            Clock::real(),
            Registry::new(),
            64,
            5.0,
            ExecutionMode::Simulated,
        );
        // not marked ready
        assert_eq!(inst.state(), InstanceState::Starting);
        assert!(inst.submit("icecube_cnn", cnn_input(1), 0).is_err());
        inst.stop();
    }

    #[test]
    fn utilization_rises_under_load() {
        let inst = sim_test_instance("t6");
        for _ in 0..20 {
            let _ = inst.submit_and_wait("icecube_cnn", cnn_input(8), 0);
        }
        let util = inst.utilization();
        assert!(util > 0.0, "utilization {util}");
        inst.stop();
    }

    #[test]
    fn unadvertised_model_rejected_even_when_in_repo() {
        // Repository holds the model, but the instance's serving set does
        // not advertise it: the modelmesh routing invariant.
        let inst = sim_test_instance("mm0");
        assert!(inst.advertises("icecube_cnn"));
        assert!(inst.unload_model("icecube_cnn"));
        assert!(!inst.advertises("icecube_cnn"));
        match inst.submit_and_wait("icecube_cnn", cnn_input(1), 0) {
            ExecOutcome::Err { status, .. } => assert_eq!(status, Status::ModelNotFound),
            other => panic!("unexpected {other:?}"),
        }
        // loading re-enables serving
        assert!(inst.load_model("icecube_cnn"));
        match inst.submit_and_wait("icecube_cnn", cnn_input(1), 0) {
            ExecOutcome::Ok { output, .. } => assert_eq!(output.shape(), &[1, 3]),
            other => panic!("unexpected {other:?}"),
        }
        inst.stop();
    }

    #[test]
    fn load_unload_bookkeeping() {
        let inst = sim_test_instance("mm1");
        // unknown-to-repo models cannot be loaded
        assert!(!inst.load_model("not_a_model"));
        // double load / double unload report false
        assert!(!inst.load_model("icecube_cnn"));
        assert!(inst.unload_model("icecube_cnn"));
        assert!(!inst.unload_model("icecube_cnn"));
        assert_eq!(inst.loaded_models(), Vec::<String>::new());
        assert_eq!(inst.memory_used(), 0);
        inst.set_loaded_models(&["icecube_cnn".into(), "not_a_model".into()]);
        assert_eq!(inst.loaded_models(), vec!["icecube_cnn".to_string()]);
        let entry = SIM_REPO.get("icecube_cnn").unwrap();
        assert_eq!(inst.memory_used(), entry.memory_bytes());
        inst.stop();
    }

    // ----- backend layer -----

    fn catalog_for(models: &[(&str, &[&str])]) -> Arc<EngineCatalog> {
        use crate::config::EnginesConfig;
        let cfgs: Vec<ModelConfig> = models
            .iter()
            .map(|(name, backends)| ModelConfig {
                name: name.to_string(),
                backends: backends.iter().map(|s| s.to_string()).collect(),
                ..ModelConfig::default()
            })
            .collect();
        Arc::new(EngineCatalog::resolve(&cfgs, &EnginesConfig::default()))
    }

    fn backend_instance(
        id: &str,
        registry: Registry,
        backends: Vec<Arc<dyn Backend>>,
        catalog: Arc<EngineCatalog>,
        load_delay: Option<Duration>,
    ) -> Arc<Instance> {
        let models = vec![ModelConfig {
            name: "icecube_cnn".into(),
            max_queue_delay: Duration::from_millis(1),
            preferred_batch: 8,
            service_model: ServiceModelConfig {
                base: Duration::from_millis(2),
                per_row: Duration::from_micros(100),
            },
            load_delay,
            backends: Vec::new(),
        }];
        let inst = Instance::start_with_opts(
            id,
            Arc::clone(&SIM_REPO),
            &models,
            Clock::real(),
            registry,
            InstanceOptions {
                exec_mode: ExecutionMode::Simulated,
                backends,
                catalog,
                ..Default::default()
            },
        );
        inst.mark_ready();
        inst
    }

    #[test]
    fn cpu_instance_serves_via_onnx_fallback() {
        use crate::metrics::registry::labels;
        let registry = Registry::new();
        let cat = catalog_for(&[("icecube_cnn", &[])]); // default prefs: pjrt first
        let inst = backend_instance(
            "be0",
            registry.clone(),
            BackendRegistry::default().for_class(AcceleratorClass::Cpu),
            cat,
            None,
        );
        assert_eq!(inst.backend_names(), vec!["onnx-sim".to_string()]);
        assert!(inst.advertises("icecube_cnn"));
        assert_eq!(inst.backend_for_model("icecube_cnn").as_deref(), Some("onnx-sim"));
        // A placement bootstrap re-applies the serving set: choosing
        // onnx-sim for a pjrt-preferring model is a counted fallback.
        inst.set_loaded_models(&["icecube_cnn".into()]);
        match inst.submit_and_wait("icecube_cnn", cnn_input(1), 0) {
            ExecOutcome::Ok { output, .. } => assert_eq!(output.shape(), &[1, 3]),
            other => panic!("unexpected {other:?}"),
        }
        // ...and the execution landed on the onnx-sim backend counter.
        let fallback = registry.counter(
            "backend_fallback_total",
            &labels(&[("instance", "be0"), ("model", "icecube_cnn")]),
        );
        assert_eq!(fallback.get(), 1, "bootstrap fallback not counted");
        let executed = registry.counter(
            "backend_inference_total",
            &labels(&[("instance", "be0"), ("backend", "onnx-sim")]),
        );
        assert!(executed.get() >= 1, "onnx-sim execution not counted");
        inst.stop();
    }

    #[test]
    fn model_config_backends_honored_without_explicit_catalog() {
        // No catalog wired in: the constructor resolves one from the
        // model list, so a `backends: [onnx-sim]` ModelConfig still
        // never lands on this default (pjrt-only) instance.
        let models = vec![ModelConfig {
            name: "icecube_cnn".into(),
            max_queue_delay: Duration::from_millis(1),
            preferred_batch: 8,
            service_model: ServiceModelConfig {
                base: Duration::from_millis(2),
                per_row: Duration::from_micros(100),
            },
            load_delay: None,
            backends: vec!["onnx-sim".into()],
            ..ModelConfig::default()
        }];
        let inst = Instance::start_with_opts(
            "be4",
            Arc::clone(&SIM_REPO),
            &models,
            Clock::real(),
            Registry::new(),
            InstanceOptions { exec_mode: ExecutionMode::Simulated, ..Default::default() },
        );
        inst.mark_ready();
        assert!(!inst.advertises("icecube_cnn"));
        assert!(!inst.load_model("icecube_cnn"));
        match inst.submit_and_wait("icecube_cnn", cnn_input(1), 0) {
            ExecOutcome::Err { status, .. } => assert_eq!(status, Status::ModelNotFound),
            other => panic!("unexpected {other:?}"),
        }
        inst.stop();
    }

    #[test]
    fn cpu_only_model_never_enters_gpu_serving_set() {
        let cat = catalog_for(&[("icecube_cnn", &["onnx-sim"])]);
        let inst = backend_instance(
            "be1",
            Registry::new(),
            BackendRegistry::default().for_class(AcceleratorClass::Gpu),
            cat,
            None,
        );
        // bootstrap skipped it, explicit loads refuse, submits see
        // ModelNotFound — the acceptance-criterion invariant at the
        // instance level.
        assert!(!inst.advertises("icecube_cnn"));
        assert!(!inst.load_model("icecube_cnn"));
        assert_eq!(inst.serving_set(), Vec::<String>::new());
        assert_eq!(inst.memory_used(), 0);
        match inst.submit_and_wait("icecube_cnn", cnn_input(1), 0) {
            ExecOutcome::Err { status, .. } => assert_eq!(status, Status::ModelNotFound),
            other => panic!("unexpected {other:?}"),
        }
        inst.stop();
    }

    #[test]
    fn backend_load_multiplier_scales_warm_window() {
        use crate::config::EnginesConfig;
        // 400 ms base load delay, onnx load multiplier 0.25 → 100 ms.
        let registry = BackendRegistry::from_config(&EnginesConfig {
            onnx_load_multiplier: 0.25,
            ..EnginesConfig::default()
        });
        let cat = catalog_for(&[("icecube_cnn", &["onnx-sim"])]);
        let inst = backend_instance(
            "be2",
            Registry::new(),
            registry.for_class(AcceleratorClass::Cpu),
            cat,
            Some(Duration::from_millis(400)),
        );
        assert!(inst.unload_model("icecube_cnn"));
        assert!(inst.load_model("icecube_cnn"));
        assert!(inst.is_loading("icecube_cnn"));
        // At 200 ms the unscaled 400 ms window would still be loading;
        // the 0.25x backend multiplier warmed it at 100 ms.
        std::thread::sleep(Duration::from_millis(200));
        assert!(
            inst.advertises("icecube_cnn"),
            "backend load multiplier not applied to the warm window"
        );
        inst.stop();
    }

    #[test]
    fn backend_memory_multiplier_scales_memory_used() {
        use crate::config::EnginesConfig;
        let registry = BackendRegistry::from_config(&EnginesConfig {
            onnx_memory_multiplier: 0.5,
            ..EnginesConfig::default()
        });
        let cat = catalog_for(&[("icecube_cnn", &["onnx-sim"])]);
        let inst = backend_instance(
            "be3",
            Registry::new(),
            registry.for_class(AcceleratorClass::Cpu),
            cat,
            None,
        );
        let entry = SIM_REPO.get("icecube_cnn").unwrap();
        let expected = (entry.memory_bytes() as f64 * 0.5).round() as u64;
        assert_eq!(inst.memory_used(), expected);
        let (_, _, snapshot_mem) = inst.placement_snapshot();
        assert_eq!(snapshot_mem, expected);
        inst.stop();
    }

    /// Instance whose model pays a real warm-load window on placement
    /// loads.
    fn slow_load_instance(id: &str, delay: Duration) -> Arc<Instance> {
        let models = vec![ModelConfig {
            name: "icecube_cnn".into(),
            max_queue_delay: Duration::from_millis(1),
            preferred_batch: 8,
            service_model: ServiceModelConfig {
                base: Duration::from_millis(2),
                per_row: Duration::from_micros(100),
            },
            load_delay: Some(delay),
            backends: Vec::new(),
            ..ModelConfig::default()
        }];
        let inst = Instance::start_with_opts(
            id,
            Arc::clone(&SIM_REPO),
            &models,
            Clock::real(),
            Registry::new(),
            InstanceOptions { exec_mode: ExecutionMode::Simulated, ..Default::default() },
        );
        inst.mark_ready();
        inst
    }

    #[test]
    fn warm_load_window_defers_advertising() {
        let inst = slow_load_instance("ld0", Duration::from_millis(150));
        // boot placement is warm immediately (startup_delay covered it)
        assert!(inst.advertises("icecube_cnn"));
        assert!(inst.unload_model("icecube_cnn"));
        // a placement load pays the window
        assert!(inst.load_model("icecube_cnn"));
        assert!(inst.is_loading("icecube_cnn"));
        assert!(!inst.advertises("icecube_cnn"));
        assert_eq!(inst.loaded_models(), Vec::<String>::new());
        assert_eq!(inst.loading_models(), vec!["icecube_cnn".to_string()]);
        assert_eq!(inst.serving_set(), vec!["icecube_cnn".to_string()]);
        // memory is committed the moment the load starts
        let entry = SIM_REPO.get("icecube_cnn").unwrap();
        assert_eq!(inst.memory_used(), entry.memory_bytes());
        // requests shed as Overloaded (retryable), not ModelNotFound
        match inst.submit_and_wait("icecube_cnn", cnn_input(1), 0) {
            ExecOutcome::Err { status, .. } => assert_eq!(status, Status::Overloaded),
            other => panic!("unexpected {other:?}"),
        }
        // double-load during the window reports false
        assert!(!inst.load_model("icecube_cnn"));
        std::thread::sleep(Duration::from_millis(200));
        assert!(inst.advertises("icecube_cnn"));
        assert!(!inst.is_loading("icecube_cnn"));
        assert_eq!(inst.loading_models(), Vec::<String>::new());
        match inst.submit_and_wait("icecube_cnn", cnn_input(1), 0) {
            ExecOutcome::Ok { output, .. } => assert_eq!(output.shape(), &[1, 3]),
            other => panic!("unexpected {other:?}"),
        }
        inst.stop();
    }

    #[test]
    fn unload_cancels_inflight_load() {
        let inst = slow_load_instance("ld1", Duration::from_millis(200));
        assert!(inst.unload_model("icecube_cnn"));
        assert!(inst.load_model("icecube_cnn"));
        assert!(inst.is_loading("icecube_cnn"));
        // cancel mid-window: memory freed, set empty
        assert!(inst.unload_model("icecube_cnn"));
        assert!(!inst.is_loading("icecube_cnn"));
        assert_eq!(inst.serving_set(), Vec::<String>::new());
        assert_eq!(inst.memory_used(), 0);
        inst.stop();
    }

    #[test]
    fn shed_from_bulk_replies_overloaded_to_victim() {
        // Slow simulated service keeps the executor busy while the
        // 2-row queue fills with bulk work.
        let models = vec![ModelConfig {
            name: "icecube_cnn".into(),
            max_queue_delay: Duration::from_millis(1),
            preferred_batch: 8,
            service_model: ServiceModelConfig {
                base: Duration::from_millis(300),
                per_row: Duration::from_micros(1),
            },
            load_delay: None,
            backends: Vec::new(),
            ..ModelConfig::default()
        }];
        let inst = Instance::start_with_opts(
            "prio0",
            Arc::clone(&SIM_REPO),
            &models,
            Clock::real(),
            Registry::new(),
            InstanceOptions {
                queue_capacity: 2,
                exec_mode: ExecutionMode::Simulated,
                ..Default::default()
            },
        );
        inst.mark_ready();
        let _busy = inst
            .submit_prio("icecube_cnn", cnn_input(1), Priority::Bulk, 0)
            .unwrap();
        std::thread::sleep(Duration::from_millis(80)); // executor picked it up
        let _b1 = inst
            .submit_prio("icecube_cnn", cnn_input(1), Priority::Bulk, 1)
            .unwrap();
        let victim_rx = inst
            .submit_prio("icecube_cnn", cnn_input(1), Priority::Bulk, 2)
            .unwrap();
        // Queue now holds capacity rows: a critical submit evicts the
        // newest bulk request instead of being rejected at ingress.
        let crit_rx = inst
            .submit_prio("icecube_cnn", cnn_input(1), Priority::Critical, 3)
            .unwrap();
        match victim_rx.recv_timeout(Duration::from_millis(100)) {
            Ok(ExecOutcome::Err { status, message }) => {
                assert_eq!(status, Status::Overloaded);
                assert!(message.contains("shed"), "{message}");
            }
            other => panic!("victim not shed promptly: {other:?}"),
        }
        match crit_rx.recv_timeout(Duration::from_secs(5)) {
            Ok(ExecOutcome::Ok { .. }) => {}
            other => panic!("critical not served: {other:?}"),
        }
        inst.stop();
    }

    #[test]
    fn simulated_mode_sleeps_service_time() {
        use crate::config::{ExecutionMode, ServiceModelConfig};
        // Metadata-only repository: no PJRT compilation at all.
        let repo = Arc::new(
            ModelRepository::load_metadata(
                std::path::Path::new("artifacts"),
                &["icecube_cnn".into()],
            )
            .unwrap(),
        );
        let models = vec![ModelConfig {
            name: "icecube_cnn".into(),
            max_queue_delay: Duration::from_millis(1),
            preferred_batch: 8,
            service_model: ServiceModelConfig {
                base: Duration::from_millis(20),
                per_row: Duration::from_millis(1),
            },
            load_delay: None,
            backends: Vec::new(),
            ..ModelConfig::default()
        }];
        let inst = Instance::start_with_mode(
            "sim0",
            repo,
            &models,
            Clock::real(),
            Registry::new(),
            64,
            5.0,
            ExecutionMode::Simulated,
        );
        inst.mark_ready();
        let t0 = std::time::Instant::now();
        match inst.submit_and_wait("icecube_cnn", cnn_input(4), 0) {
            ExecOutcome::Ok { output, compute_us, .. } => {
                assert_eq!(output.shape(), &[4, 3]);
                assert!(output.data().iter().all(|&v| v == 0.0));
                // padded to engine batch 4: 20ms + 4*1ms = 24ms
                assert!(compute_us >= 20_000, "compute {compute_us}us");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(t0.elapsed() >= Duration::from_millis(20));
        inst.stop();
    }

    #[test]
    fn simulated_mode_respects_time_dilation() {
        use crate::config::{ExecutionMode, ServiceModelConfig};
        let repo = Arc::new(
            ModelRepository::load_metadata(
                std::path::Path::new("artifacts"),
                &["icecube_cnn".into()],
            )
            .unwrap(),
        );
        let models = vec![ModelConfig {
            name: "icecube_cnn".into(),
            max_queue_delay: Duration::from_millis(1),
            preferred_batch: 8,
            service_model: ServiceModelConfig {
                base: Duration::from_millis(200),
                per_row: Duration::from_millis(0),
            },
            load_delay: None,
            backends: Vec::new(),
            ..ModelConfig::default()
        }];
        // 20x dilation: the 200ms (clock) service takes ~10ms real.
        let inst = Instance::start_with_mode(
            "sim1",
            repo,
            &models,
            Clock::scaled(20.0),
            Registry::new(),
            64,
            5.0,
            ExecutionMode::Simulated,
        );
        inst.mark_ready();
        let t0 = std::time::Instant::now();
        match inst.submit_and_wait("icecube_cnn", cnn_input(1), 0) {
            ExecOutcome::Ok { compute_us, .. } => {
                // compute is measured in clock time: ~200ms
                assert!(compute_us >= 150_000, "compute {compute_us}us");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(t0.elapsed() < Duration::from_millis(150), "took {:?}", t0.elapsed());
        inst.stop();
    }

    #[test]
    fn traced_request_records_server_spans() {
        use crate::metrics::registry::labels;
        let clock = Clock::real();
        let registry = Registry::new();
        let tracer = Tracer::new(clock.clone(), 256, true);
        let models = vec![ModelConfig {
            name: "icecube_cnn".into(),
            max_queue_delay: Duration::from_millis(1),
            preferred_batch: 8,
            service_model: ServiceModelConfig {
                base: Duration::from_millis(2),
                per_row: Duration::from_micros(100),
            },
            load_delay: None,
            backends: Vec::new(),
            ..ModelConfig::default()
        }];
        let inst = Instance::start_with_opts(
            "tspan0",
            Arc::clone(&SIM_REPO),
            &models,
            clock,
            registry.clone(),
            InstanceOptions {
                exec_mode: ExecutionMode::Simulated,
                tracer: tracer.clone(),
                ..Default::default()
            },
        );
        inst.mark_ready();
        match inst.submit_and_wait("icecube_cnn", cnn_input(1), 77) {
            ExecOutcome::Ok { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        let v = tracer.trace(77);
        let names: Vec<&str> = v.spans.iter().map(|s| s.name.as_str()).collect();
        assert!(names.contains(&"queue"), "{names:?}");
        assert!(names.contains(&"batch"), "{names:?}");
        assert!(names.contains(&"compute"), "{names:?}");
        assert!(v.duration_of("compute") > 0.0);
        // The per-(model, priority) queue-wait histogram observed it.
        let h = registry.histogram(
            "queue_wait_seconds",
            &labels(&[
                ("instance", "tspan0"),
                ("model", "icecube_cnn"),
                ("priority", "standard"),
            ]),
        );
        assert_eq!(h.snapshot().count(), 1);
        inst.stop();
    }

    #[test]
    fn stop_drains_and_joins() {
        let inst = sim_test_instance("t7");
        let rx = inst.submit("icecube_cnn", cnn_input(1), 0).unwrap();
        inst.stop();
        // queued request either served or rejected, never lost
        assert!(rx.recv().is_ok());
        assert_eq!(inst.state(), InstanceState::Stopped);
        assert!(inst.submit("icecube_cnn", cnn_input(1), 0).is_err());
    }
}
