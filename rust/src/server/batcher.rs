//! Dynamic batching queue — Triton's "dynamic_batching" policy (§2.1),
//! with model-affinity admission and request-priority lanes.
//!
//! Requests land in a per-instance [`BatchQueue`] that keeps one
//! sub-queue per (model, [`Priority`]) — the admission lanes. Batches
//! never interleave models, a model's backlog is directly observable
//! ([`BatchQueue::depth_for`] — the signal the placement controller
//! folds into its demand estimate), and within a model the lanes order
//! service by urgency: `critical` ahead of `standard` ahead of `bulk`.
//!
//! How the executor picks *which* lane to serve is the
//! [`BatchMode`](crate::config::BatchMode):
//!
//! * **`Affinity`** (default): serve any lane whose head request has
//!   outlived its batching window — higher priority first, then oldest
//!   head; else any lane whose accumulated rows reached the preferred
//!   batch — higher priority first, then most rows (a ready critical
//!   batch preempts an accumulating bulk window); else sleep until the
//!   earliest deadline. A cold model's half-empty window never blocks a
//!   hot model's ready batch, and a bulk backlog never delays a
//!   critical head past its own `max_queue_delay`.
//! * **`Fifo`**: always serve the model of the globally oldest request,
//!   waiting out that model's window first — strict arrival order,
//!   priority-blind, kept as the ablation baseline.
//!
//! Within a (model, priority) lane, requests are always served in
//! arrival order, and every lane head is flushed no later than its
//! `max_queue_delay` *subject to priority*: an expired higher-priority
//! head anywhere in the queue is served first (under sustained critical
//! saturation, bulk waits — that is the point of the lanes). Waiting is
//! bounded, though: **anti-starvation aging** (`server.priorities.
//! max_bulk_wait`, zero = off) promotes a below-critical head that has
//! waited past the bound to the front of the next pop — ahead of every
//! un-aged lane, oldest aged head first — so sustained critical
//! saturation delays bulk but can never starve it forever.
//!
//! The queue is also where overload protection lands: admission is
//! bounded by total queued **rows** (multi-row requests count their
//! real weight, not one slot). A push over the bound first tries
//! **shed-from-bulk**: the newest strictly-lower-priority requests are
//! evicted (answered `Overloaded`) to make room, so an incoming
//! critical request is never rejected while bulk work occupies the
//! queue. Only when no lower-priority rows remain is the push itself
//! rejected for the gateway to shed at ingress (§2.2).

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::config::BatchMode;
use crate::rpc::codec::{Priority, Status};
use crate::runtime::Tensor;
use crate::telemetry::{Span, Tracer};
use crate::util::clock::{Clock, Nanos};

/// Batching knobs for one model (from `config::ModelConfig`).
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Hold the head request at most this long while accumulating.
    pub max_queue_delay: Duration,
    /// Stop accumulating at this many rows.
    pub preferred_rows: usize,
    /// Hard cap on rows per popped batch — the model's largest compiled
    /// engine batch (Triton's `max_batch_size`). Folding beyond it would
    /// only chain engine calls serially while hiding per-request queue
    /// time from the autoscaler trigger.
    pub max_rows: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_queue_delay: Duration::from_millis(2),
            preferred_rows: 8,
            max_rows: 64,
        }
    }
}

/// Executor's reply to one queued request.
#[derive(Debug)]
pub enum ExecOutcome {
    Ok {
        output: Tensor,
        queue_us: u32,
        compute_us: u32,
        batch_rows: u32,
    },
    Err {
        status: Status,
        message: String,
    },
}

/// One queued request.
pub struct Pending {
    pub model: String,
    /// Admission lane within the model (shed order, service order).
    pub priority: Priority,
    pub input: Tensor,
    pub enqueued: Nanos,
    pub trace_id: u64,
    pub reply: mpsc::Sender<ExecOutcome>,
}

impl Pending {
    /// Rows this request contributes to a batch.
    pub fn rows(&self) -> usize {
        self.input.batch()
    }
}

/// One (model, priority) admission lane: requests in arrival order,
/// tagged with a queue-global sequence number so `Fifo` mode can
/// reconstruct the global arrival order across lanes.
struct Lane {
    queue: VecDeque<(u64, Pending)>,
    rows: usize,
}

impl Lane {
    fn new() -> Self {
        Lane { queue: VecDeque::new(), rows: 0 }
    }
}

/// One model's admission group: one lane per priority class, indexed by
/// [`Priority::index`] (0 = bulk .. 2 = critical).
struct Group {
    lanes: [Lane; Priority::COUNT],
}

impl Group {
    fn new() -> Self {
        Group { lanes: std::array::from_fn(|_| Lane::new()) }
    }

    /// Queued requests across lanes.
    fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.queue.len()).sum()
    }

    /// Queued rows across lanes.
    fn rows(&self) -> usize {
        self.lanes.iter().map(|l| l.rows).sum()
    }

    fn is_empty(&self) -> bool {
        self.lanes.iter().all(|l| l.queue.is_empty())
    }

    /// Lane index holding the globally oldest request of this group.
    fn oldest_lane(&self) -> Option<usize> {
        (0..Priority::COUNT)
            .filter(|&i| !self.lanes[i].queue.is_empty())
            .min_by_key(|&i| self.lanes[i].queue[0].0)
    }
}

struct Inner {
    groups: BTreeMap<String, Group>,
    /// Total queued requests across groups (the demand-signal depth).
    len: usize,
    /// Total queued rows across groups (the admission bound).
    rows: usize,
    next_seq: u64,
    draining: bool,
    /// Times a higher-priority lane was served past an older
    /// lower-priority request (the preemption counter).
    preemptions: u64,
}

/// What the selection pass decided to do.
enum Pick {
    /// Serve this model now; `lane` targets one priority lane
    /// (`None` = priority-blind global arrival order, the `Fifo` path).
    Serve { model: String, lane: Option<usize> },
    /// Nothing servable yet; earliest head deadline in clock nanos.
    WaitUntil(Nanos),
}

/// Bounded, condvar-signalled batch queue with per-(model, priority)
/// admission lanes.
pub struct BatchQueue {
    inner: Mutex<Inner>,
    available: Condvar,
    /// Admission bound in total queued rows (a single over-large request
    /// is still admitted into an empty queue and pops alone).
    capacity: usize,
    mode: BatchMode,
    /// Anti-starvation aging bound for below-critical lane heads
    /// (`server.priorities.max_bulk_wait`; zero disables aging).
    max_bulk_wait: Duration,
    /// Records per-request enqueue→pop "queue" spans against the
    /// propagated trace id (disabled by default; see
    /// [`BatchQueue::with_tracer`]).
    tracer: Tracer,
}

impl BatchQueue {
    /// Queue holding at most `capacity` rows, with the default
    /// model-affinity admission.
    pub fn new(capacity: usize) -> Self {
        Self::with_mode(capacity, BatchMode::Affinity)
    }

    /// Queue with an explicit admission mode (`Fifo` is the ablation
    /// baseline) and aging disabled.
    pub fn with_mode(capacity: usize, mode: BatchMode) -> Self {
        Self::with_aging(capacity, mode, Duration::ZERO)
    }

    /// [`BatchQueue::with_mode`] with an anti-starvation aging bound: a
    /// below-critical lane head older than `max_bulk_wait` is promoted
    /// to the front of priority-first selection (zero disables).
    pub fn with_aging(capacity: usize, mode: BatchMode, max_bulk_wait: Duration) -> Self {
        BatchQueue {
            inner: Mutex::new(Inner {
                groups: BTreeMap::new(),
                len: 0,
                rows: 0,
                next_seq: 0,
                draining: false,
                preemptions: 0,
            }),
            available: Condvar::new(),
            capacity,
            mode,
            max_bulk_wait,
            tracer: Tracer::disabled(),
        }
    }

    /// Attach a tracer: every popped request records a "queue" span from
    /// its enqueue time to the pop (the per-(model, priority) queue wait
    /// of the latency breakdown).
    pub fn with_tracer(mut self, tracer: Tracer) -> Self {
        self.tracer = tracer;
        self
    }

    /// Enqueue a request.
    ///
    /// Success returns the requests evicted to make room (empty in the
    /// common case): when the row bound is hit, the newest strictly
    /// lower-priority requests are shed first (shed-from-bulk) — the
    /// caller must answer each victim `Overloaded`. Fails fast when
    /// draining, or when full and no lower-priority rows can be shed.
    pub fn push(&self, pending: Pending) -> Result<Vec<Pending>, Pending> {
        let mut inner = self.inner.lock().unwrap();
        if inner.draining {
            return Err(pending);
        }
        let rows = pending.rows();
        let mut evicted = Vec::new();
        if inner.len > 0 && inner.rows + rows > self.capacity {
            // Shed-from-bulk: can evicting strictly lower-priority
            // requests (never equal-or-higher) make enough room?
            let lane_cap = pending.priority.index();
            let evictable: usize = inner
                .groups
                .values()
                .flat_map(|g| g.lanes[..lane_cap].iter())
                .map(|l| l.rows)
                .sum();
            if inner.rows + rows > self.capacity + evictable {
                return Err(pending);
            }
            while inner.rows + rows > self.capacity {
                // Victim: the newest (highest seq) lower-priority request.
                let mut victim: Option<(u64, String, usize)> = None;
                for (model, group) in &inner.groups {
                    for (li, lane) in group.lanes[..lane_cap].iter().enumerate() {
                        if let Some(&(seq, _)) = lane.queue.back() {
                            if victim.as_ref().is_none_or(|v| seq > v.0) {
                                victim = Some((seq, model.clone(), li));
                            }
                        }
                    }
                }
                let Some((_, model, li)) = victim else {
                    // Unreachable given the feasibility check above.
                    break;
                };
                let group = inner.groups.get_mut(&model).expect("victim group exists");
                let (_, p) = group.lanes[li].queue.pop_back().expect("victim exists");
                let r = p.rows();
                group.lanes[li].rows -= r;
                if group.is_empty() {
                    inner.groups.remove(&model);
                }
                inner.rows -= r;
                inner.len -= 1;
                evicted.push(p);
            }
        }
        let seq = inner.next_seq;
        inner.next_seq += 1;
        inner.len += 1;
        inner.rows += rows;
        let li = pending.priority.index();
        let group = inner
            .groups
            .entry(pending.model.clone())
            .or_insert_with(Group::new);
        group.lanes[li].rows += rows;
        group.lanes[li].queue.push_back((seq, pending));
        self.available.notify_one();
        Ok(evicted)
    }

    /// Current queue depth (requests, all models and priorities — the
    /// demand signal stays request-count-based).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().len
    }

    /// Current queued rows (what the admission bound counts).
    pub fn rows_queued(&self) -> usize {
        self.inner.lock().unwrap().rows
    }

    /// Queued requests for one model — the per-model backlog the
    /// placement demand signal consumes.
    pub fn depth_for(&self, model: &str) -> usize {
        self.inner
            .lock()
            .unwrap()
            .groups
            .get(model)
            .map(|g| g.len())
            .unwrap_or(0)
    }

    /// Per-model depth snapshot under a single lock acquisition (the
    /// executor's gauge refresh — one `depth_for` per model would take
    /// the hot-path mutex once per model per wakeup). Groups whose
    /// queues emptied are dropped on pop, so no zero-depth rows linger
    /// for models long since unloaded.
    pub fn depths(&self) -> Vec<(String, usize)> {
        self.inner
            .lock()
            .unwrap()
            .groups
            .iter()
            .filter(|(_, g)| !g.is_empty())
            .map(|(m, g)| (m.clone(), g.len()))
            .collect()
    }

    /// Queued requests for one model, split by priority class and
    /// indexed by [`Priority::index`] — the priority-aware backlog the
    /// placement demand signal weights (a critical backlog should
    /// attract replicas harder than an equal bulk backlog).
    pub fn priority_depth_for(&self, model: &str) -> [usize; Priority::COUNT] {
        let inner = self.inner.lock().unwrap();
        let mut out = [0usize; Priority::COUNT];
        if let Some(group) = inner.groups.get(model) {
            for (li, lane) in group.lanes.iter().enumerate() {
                out[li] = lane.queue.len();
            }
        }
        out
    }

    /// Queued requests per priority class across all models, indexed by
    /// [`Priority::index`] — one lock acquisition for the per-priority
    /// depth gauges.
    pub fn priority_depths(&self) -> [usize; Priority::COUNT] {
        let inner = self.inner.lock().unwrap();
        let mut out = [0usize; Priority::COUNT];
        for group in inner.groups.values() {
            for (li, lane) in group.lanes.iter().enumerate() {
                out[li] += lane.queue.len();
            }
        }
        out
    }

    /// Times a higher-priority lane was served past an older queued
    /// lower-priority request (monotonic; feeds
    /// `batch_preemptions_total`).
    pub fn preemptions(&self) -> u64 {
        self.inner.lock().unwrap().preemptions
    }

    /// Mark draining: pushes fail, pops continue until empty.
    pub fn drain(&self) {
        self.inner.lock().unwrap().draining = true;
        self.available.notify_all();
    }

    /// True once draining and empty.
    pub fn drained(&self) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.draining && inner.len == 0
    }

    /// Decide which lane to serve, or how long to wait. `Draining`
    /// flushes everything immediately (priority order, then oldest
    /// head).
    fn select<F>(&self, inner: &Inner, now: Nanos, policy_for: &F) -> Pick
    where
        F: Fn(&str) -> BatchPolicy,
    {
        if self.mode == BatchMode::Fifo && !inner.draining {
            // Global arrival order, priority-blind: the model of the
            // oldest request, held until its own target/deadline
            // (head-of-line semantics — the ablation baseline).
            let (model, head_enq) = inner
                .groups
                .iter()
                .filter_map(|(m, g)| {
                    g.oldest_lane()
                        .map(|li| (m, g.lanes[li].queue[0].0, g.lanes[li].queue[0].1.enqueued))
                })
                .min_by_key(|&(_, seq, _)| seq)
                .map(|(m, _, enq)| (m.clone(), enq))
                .expect("select called with requests queued");
            let policy = policy_for(&model);
            let group = &inner.groups[&model];
            let target = policy.preferred_rows.min(policy.max_rows).max(1);
            let deadline = head_enq + policy.max_queue_delay.as_nanos() as Nanos;
            if group.rows() >= target || now >= deadline {
                return Pick::Serve { model, lane: None };
            }
            return Pick::WaitUntil(deadline);
        }

        // Affinity (and any draining flush): expired heads first —
        // priority order, then oldest head — so the latency bound holds
        // per lane and urgency wins ties across lanes. Anti-starvation
        // aging folds in here: a below-critical head older than
        // `max_bulk_wait` competes at an *effective* priority above
        // critical (oldest aged head first), so it is served in the
        // very next pop no matter how deep the higher lanes are.
        let aging = self.max_bulk_wait.as_nanos() as Nanos;
        // (effective priority, enqueued, model, actual lane index)
        let mut expired: Option<(usize, Nanos, String, usize)> = None;
        let mut ready: Option<(usize, usize, String)> = None;
        let mut earliest: Option<Nanos> = None;
        for (model, group) in &inner.groups {
            let policy = policy_for(model);
            let target = policy.preferred_rows.min(policy.max_rows).max(1);
            for (li, lane) in group.lanes.iter().enumerate().rev() {
                let Some((_, head)) = lane.queue.front() else { continue };
                let deadline = head.enqueued + policy.max_queue_delay.as_nanos() as Nanos;
                let aged = aging > 0 && li < Priority::COUNT - 1 && now >= head.enqueued + aging;
                let eff = if aged { Priority::COUNT } else { li };
                if inner.draining || aged || now >= deadline {
                    let better = expired
                        .as_ref()
                        .is_none_or(|&(p, e, _, _)| eff > p || (eff == p && head.enqueued < e));
                    if better {
                        expired = Some((eff, head.enqueued, model.clone(), li));
                    }
                } else if lane.rows >= target {
                    let better = ready
                        .as_ref()
                        .is_none_or(|&(p, r, _)| li > p || (li == p && lane.rows > r));
                    if better {
                        ready = Some((li, lane.rows, model.clone()));
                    }
                } else {
                    // Wake at whichever comes first: the batching
                    // deadline or the head crossing the aging bound.
                    let mut wake = deadline;
                    if aging > 0 && li < Priority::COUNT - 1 {
                        wake = wake.min(head.enqueued + aging);
                    }
                    if earliest.as_ref().is_none_or(|e| wake < *e) {
                        earliest = Some(wake);
                    }
                }
            }
        }
        if let Some((_, _, model, lane)) = expired {
            return Pick::Serve { model, lane: Some(lane) };
        }
        if let Some((lane, _, model)) = ready {
            return Pick::Serve { model, lane: Some(lane) };
        }
        Pick::WaitUntil(earliest.expect("some non-empty group has no pick"))
    }

    /// Pop one same-model batch according to `policy_for` and the
    /// queue's [`BatchMode`].
    ///
    /// Blocks up to `idle_timeout` waiting for a first request; returns
    /// `None` on timeout (the executor uses idle wakeups to refresh
    /// utilization gauges) or when draining and empty.
    ///
    /// The policy's `max_rows` caps the batch at the largest compiled
    /// engine batch. A single over-large request is returned alone (the
    /// executor splits it across engine calls). An affinity pop drains
    /// the selected priority lane in arrival order, then fills the
    /// remaining row budget from the model's other lanes (highest
    /// priority first) — lower-priority rows ride along for free, they
    /// never displace the selected lane.
    pub fn pop_batch<F>(
        &self,
        clock: &Clock,
        policy_for: F,
        idle_timeout: Duration,
    ) -> Option<Vec<Pending>>
    where
        F: Fn(&str) -> BatchPolicy,
    {
        let mut inner = self.inner.lock().unwrap();

        // Phase 1: wait for a first request.
        let wait_start = std::time::Instant::now();
        while inner.len == 0 {
            if inner.draining {
                return None;
            }
            let remaining = idle_timeout.checked_sub(wait_start.elapsed())?;
            let (guard, timeout) = self
                .available
                .wait_timeout(inner, remaining.min(Duration::from_millis(50)))
                .unwrap();
            inner = guard;
            if timeout.timed_out()
                && wait_start.elapsed() >= idle_timeout
                && inner.len == 0
            {
                return None;
            }
        }

        // Phase 2: pick a lane, waiting out batching windows as the
        // mode dictates. New pushes re-run the selection.
        let (model, lane) = loop {
            if inner.len == 0 {
                // Drained out from under us (defensive: single-consumer
                // queues cannot shrink here, but the contract allows it).
                if inner.draining {
                    return None;
                }
                let (guard, _) = self
                    .available
                    .wait_timeout(inner, Duration::from_millis(20))
                    .unwrap();
                inner = guard;
                continue;
            }
            let now = clock.now();
            match self.select(&inner, now, &policy_for) {
                Pick::Serve { model, lane } => break (model, lane),
                Pick::WaitUntil(deadline) => {
                    // Convert the *clock-time* deadline into a bounded
                    // real-time wait; the cap re-checks under dilation.
                    let clock_remaining = Duration::from_nanos(deadline.saturating_sub(now));
                    let wait = clock_remaining.min(Duration::from_millis(20));
                    let (guard, _) = self.available.wait_timeout(inner, wait).unwrap();
                    inner = guard;
                }
            }
        };

        // Preemption bookkeeping (counted after the pop): serving this
        // lane is a preemption only if an older, strictly-lower-priority
        // request is STILL queued afterwards — lower-priority requests
        // that ride along in the popped batch were not jumped.
        let served = match lane {
            Some(li) if !inner.draining && li > 0 => {
                Some((li, inner.groups[&model].lanes[li].queue[0].0))
            }
            _ => None,
        };

        // Phase 3: pop the lane's requests in arrival order up to the
        // row budget. An oversized head goes alone.
        let policy = policy_for(&model);
        let max_rows = policy.max_rows.max(1);
        let group = inner.groups.get_mut(&model).expect("selected group exists");
        let mut batch = Vec::new();
        let mut rows = 0usize;
        match lane {
            Some(li) => {
                Self::take_from_lane(&mut group.lanes[li], &mut batch, &mut rows, max_rows);
                // Top up from the model's other lanes, urgent first.
                for (l2, lane2) in group.lanes.iter_mut().enumerate().rev() {
                    if l2 != li {
                        Self::take_from_lane(lane2, &mut batch, &mut rows, max_rows);
                    }
                }
            }
            None => {
                // Fifo: global arrival order across the model's lanes.
                loop {
                    let Some(li) = group.oldest_lane() else { break };
                    let r = group.lanes[li].queue[0].1.rows();
                    if batch.is_empty() && r > max_rows {
                        let (_, p) = group.lanes[li].queue.pop_front().unwrap();
                        group.lanes[li].rows -= r;
                        rows += r;
                        batch.push(p);
                        break;
                    }
                    if rows + r > max_rows {
                        break;
                    }
                    let (_, p) = group.lanes[li].queue.pop_front().unwrap();
                    group.lanes[li].rows -= r;
                    rows += r;
                    batch.push(p);
                }
            }
        }
        if group.is_empty() {
            inner.groups.remove(&model);
        }
        inner.rows -= rows.min(inner.rows);
        inner.len -= batch.len();
        if let Some((li, served_seq)) = served {
            let jumped = inner.groups.values().any(|g| {
                g.lanes[..li]
                    .iter()
                    .any(|l| l.queue.front().is_some_and(|&(s, _)| s < served_seq))
            });
            if jumped {
                inner.preemptions += 1;
            }
        }
        // The selected lane always has a head and the first iteration
        // always takes it (an oversized head goes alone), so a selected
        // pop can never come back empty.
        debug_assert!(!batch.is_empty());
        drop(inner);
        if self.tracer.enabled() {
            let popped = clock.now_secs();
            for p in &batch {
                self.tracer.record(Span {
                    trace_id: p.trace_id,
                    name: "queue".into(),
                    start: p.enqueued as f64 / 1e9,
                    end: popped,
                });
            }
        }
        Some(batch)
    }

    /// Move requests off `lane`'s front into `batch` while they fit the
    /// row budget; an oversized head is taken alone into an empty batch.
    fn take_from_lane(
        lane: &mut Lane,
        batch: &mut Vec<Pending>,
        rows: &mut usize,
        max_rows: usize,
    ) {
        while let Some((_, p)) = lane.queue.front() {
            let r = p.rows();
            if batch.is_empty() && r > max_rows {
                let (_, p) = lane.queue.pop_front().unwrap();
                lane.rows -= r;
                *rows += r;
                batch.push(p);
                return;
            }
            if *rows + r > max_rows {
                return;
            }
            let (_, p) = lane.queue.pop_front().unwrap();
            lane.rows -= r;
            *rows += r;
            batch.push(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn pending_prio(
        model: &str,
        rows: usize,
        priority: Priority,
        trace_id: u64,
        clock: &Clock,
    ) -> (Pending, mpsc::Receiver<ExecOutcome>) {
        let (tx, rx) = mpsc::channel();
        let shape = vec![rows, 2];
        (
            Pending {
                model: model.into(),
                priority,
                input: Tensor::zeros(shape),
                enqueued: clock.now(),
                trace_id,
                reply: tx,
            },
            rx,
        )
    }

    fn pending(model: &str, rows: usize, clock: &Clock) -> (Pending, mpsc::Receiver<ExecOutcome>) {
        pending_prio(model, rows, Priority::Standard, 0, clock)
    }

    fn policy(delay_ms: u64, rows: usize, max_rows: usize) -> impl Fn(&str) -> BatchPolicy {
        move |_| BatchPolicy {
            max_queue_delay: Duration::from_millis(delay_ms),
            preferred_rows: rows,
            max_rows,
        }
    }

    #[test]
    fn pops_immediately_at_preferred_rows() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        for _ in 0..4 {
            let (p, _rx) = pending("m", 2, &clock);
            q.push(p).map_err(|_| ()).unwrap();
        }
        let batch = q
            .pop_batch(&clock, policy(1000, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.iter().map(|p| p.rows()).sum::<usize>(), 8);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        let (p, _rx) = pending("m", 1, &clock);
        q.push(p).map_err(|_| ()).unwrap();
        let t0 = std::time::Instant::now();
        let batch = q
            .pop_batch(&clock, policy(30, 8, 16), Duration::from_millis(500))
            .unwrap();
        assert_eq!(batch.len(), 1);
        // must have waited ~the queue delay, not the idle timeout
        assert!(t0.elapsed() < Duration::from_millis(300));
    }

    #[test]
    fn same_model_runs_only() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        let (pa, _r1) = pending("a", 1, &clock);
        let (pb, _r2) = pending("b", 1, &clock);
        let (pa2, _r3) = pending("a", 1, &clock);
        q.push(pa).map_err(|_| ()).unwrap();
        q.push(pb).map_err(|_| ()).unwrap();
        q.push(pa2).map_err(|_| ()).unwrap();
        let batch = q
            .pop_batch(&clock, policy(5, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|p| p.model == "a"));
        assert_eq!(q.depth(), 1); // "b" stays
        assert_eq!(q.depth_for("b"), 1);
        assert_eq!(q.depth_for("a"), 0);
    }

    #[test]
    fn row_budget_respected() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        for _ in 0..5 {
            let (p, _rx) = pending("m", 4, &clock);
            q.push(p).map_err(|_| ()).unwrap();
        }
        let batch = q
            .pop_batch(&clock, policy(5, 100, 10), Duration::from_millis(100))
            .unwrap();
        // 4+4 = 8 fits; adding the third (12 > 10) does not.
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn oversized_request_pops_alone() {
        let clock = Clock::real();
        let q = BatchQueue::new(128);
        let (p, _rx) = pending("m", 100, &clock);
        q.push(p).map_err(|_| ()).unwrap();
        let (p2, _rx2) = pending("m", 1, &clock);
        q.push(p2).map_err(|_| ()).unwrap();
        let batch = q
            .pop_batch(&clock, policy(5, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].rows(), 100);
    }

    #[test]
    fn capacity_rejects() {
        let clock = Clock::real();
        let q = BatchQueue::new(2);
        let (p1, _r1) = pending("m", 1, &clock);
        let (p2, _r2) = pending("m", 1, &clock);
        let (p3, _r3) = pending("m", 1, &clock);
        assert!(q.push(p1).is_ok());
        assert!(q.push(p2).is_ok());
        assert!(q.push(p3).is_err());
    }

    /// Regression (overload-accounting bug): the bound must count rows,
    /// not requests — a few multi-row requests used to sail past a
    /// request-count check.
    #[test]
    fn capacity_bounds_rows_not_requests() {
        let clock = Clock::real();
        let q = BatchQueue::new(16);
        let (p1, _r1) = pending("m", 8, &clock);
        let (p2, _r2) = pending("m", 8, &clock);
        assert!(q.push(p1).is_ok());
        assert!(q.push(p2).is_ok());
        assert_eq!(q.rows_queued(), 16);
        // Two requests is nowhere near 16 *requests*, but a third
        // 8-row tensor would put 24 rows behind a 16-row bound.
        let (p3, _r3) = pending("m", 8, &clock);
        assert!(q.push(p3).is_err(), "multi-row push sailed past the row bound");
        assert_eq!(q.depth(), 2);
        assert_eq!(q.rows_queued(), 16);
    }

    /// Regression (leak): groups whose queues emptied must not linger in
    /// `groups` (and `depths()` must not emit zero-depth rows for them).
    #[test]
    fn empty_groups_dropped_after_pop() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        for model in ["a", "b"] {
            let (p, _rx) = pending(model, 1, &clock);
            q.push(p).map_err(|_| ()).unwrap();
            let batch = q
                .pop_batch(&clock, policy(1, 1, 16), Duration::from_millis(100))
                .unwrap();
            assert_eq!(batch.len(), 1);
        }
        assert_eq!(q.depth(), 0);
        assert_eq!(q.depth_for("a"), 0);
        assert_eq!(q.depth_for("b"), 0);
        assert!(
            q.depths().is_empty(),
            "served models still emit depth rows: {:?}",
            q.depths()
        );
    }

    #[test]
    fn drain_rejects_pushes_and_unblocks() {
        let clock = Clock::real();
        let q = Arc::new(BatchQueue::new(8));
        q.drain();
        let (p, _rx) = pending("m", 1, &clock);
        assert!(q.push(p).is_err());
        assert!(q
            .pop_batch(&clock, policy(5, 8, 16), Duration::from_millis(50))
            .is_none());
        assert!(q.drained());
    }

    #[test]
    fn drain_flushes_queued_requests() {
        let clock = Clock::real();
        let q = BatchQueue::new(8);
        let (p, _rx) = pending("m", 1, &clock);
        q.push(p).map_err(|_| ()).unwrap();
        q.drain();
        // long window, but draining flushes immediately
        let t0 = std::time::Instant::now();
        let batch = q
            .pop_batch(&clock, policy(5000, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(100));
        assert!(q.drained());
    }

    #[test]
    fn idle_timeout_returns_none() {
        let clock = Clock::real();
        let q = BatchQueue::new(8);
        let t0 = std::time::Instant::now();
        assert!(q
            .pop_batch(&clock, policy(5, 8, 16), Duration::from_millis(40))
            .is_none());
        assert!(t0.elapsed() >= Duration::from_millis(35));
    }

    #[test]
    fn push_wakes_blocked_pop() {
        let clock = Clock::real();
        let q = Arc::new(BatchQueue::new(8));
        let q2 = Arc::clone(&q);
        let c2 = clock.clone();
        let h = std::thread::spawn(move || {
            q2.pop_batch(&c2, policy(1, 1, 16), Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(20));
        let (p, _rx) = pending("m", 1, &clock);
        q.push(p).map_err(|_| ()).unwrap();
        let batch = h.join().unwrap().unwrap();
        assert_eq!(batch.len(), 1);
    }

    /// Per-model policies for the affinity-vs-fifo pair below: the cold
    /// model holds a wide batching window, the hot model a narrow one.
    fn mixed_policy(model: &str) -> BatchPolicy {
        match model {
            "cold" => BatchPolicy {
                max_queue_delay: Duration::from_millis(120),
                preferred_rows: 8,
                max_rows: 16,
            },
            _ => BatchPolicy {
                max_queue_delay: Duration::from_millis(120),
                preferred_rows: 4,
                max_rows: 16,
            },
        }
    }

    #[test]
    fn affinity_serves_ready_model_past_blocked_head() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        // cold arrives first (the queue head) but never fills its batch
        let (pc, _rc) = pending("cold", 1, &clock);
        q.push(pc).map_err(|_| ()).unwrap();
        let mut _rxs = Vec::new();
        for _ in 0..4 {
            let (p, rx) = pending("hot", 1, &clock);
            q.push(p).map_err(|_| ()).unwrap();
            _rxs.push(rx);
        }
        // hot reached its preferred rows: affinity serves it immediately,
        // long before cold's 120 ms window expires
        let t0 = std::time::Instant::now();
        let batch = q
            .pop_batch(&clock, mixed_policy, Duration::from_millis(500))
            .unwrap();
        assert!(batch.iter().all(|p| p.model == "hot"), "served the blocked head first");
        assert_eq!(batch.len(), 4);
        assert!(t0.elapsed() < Duration::from_millis(60), "waited on cold's window");
        // cold still flushes by its own deadline
        let batch = q
            .pop_batch(&clock, mixed_policy, Duration::from_millis(500))
            .unwrap();
        assert!(batch.iter().all(|p| p.model == "cold"));
    }

    #[test]
    fn fifo_head_of_line_blocks_ready_model() {
        let clock = Clock::real();
        let q = BatchQueue::with_mode(64, BatchMode::Fifo);
        let (pc, _rc) = pending("cold", 1, &clock);
        q.push(pc).map_err(|_| ()).unwrap();
        for _ in 0..4 {
            let (p, _rx) = pending("hot", 1, &clock);
            q.push(p).map_err(|_| ()).unwrap();
        }
        // strict arrival order: cold is served first, after waiting out
        // its full batching window, even though hot has a ready batch
        let t0 = std::time::Instant::now();
        let batch = q
            .pop_batch(&clock, mixed_policy, Duration::from_millis(500))
            .unwrap();
        assert!(batch.iter().all(|p| p.model == "cold"));
        assert!(
            t0.elapsed() >= Duration::from_millis(100),
            "fifo did not wait out the head's window: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn affinity_expired_heads_flush_oldest_first() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        let (pa, _ra) = pending("a", 1, &clock);
        q.push(pa).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let (pb, _rb) = pending("b", 1, &clock);
        q.push(pb).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        // both expired (1 ms windows): oldest head ("a") first
        let batch = q
            .pop_batch(&clock, policy(1, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert!(batch.iter().all(|p| p.model == "a"));
        let batch = q
            .pop_batch(&clock, policy(1, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert!(batch.iter().all(|p| p.model == "b"));
    }

    // ----- priority lanes -----

    #[test]
    fn expired_heads_served_in_priority_order() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        // bulk arrives first, critical second; both expire (1 ms window)
        let (pb, _rb) = pending_prio("m", 1, Priority::Bulk, 1, &clock);
        q.push(pb).map_err(|_| ()).unwrap();
        let (pc, _rc) = pending_prio("m", 1, Priority::Critical, 2, &clock);
        q.push(pc).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        // one batch: the critical lane is selected, and the bulk request
        // rides along in the same same-model batch (row budget permits),
        // with the critical request first.
        let batch = q
            .pop_batch(&clock, policy(1, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].priority, Priority::Critical);
        assert_eq!(batch[1].priority, Priority::Bulk);
    }

    #[test]
    fn ready_critical_batch_preempts_accumulating_bulk_window() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        // bulk accumulating in a wide window, across a DIFFERENT model so
        // it cannot ride along; critical fills its preferred batch.
        let (pb, _rb) = pending_prio("bulkmodel", 2, Priority::Bulk, 1, &clock);
        q.push(pb).map_err(|_| ()).unwrap();
        let mut _rxs = Vec::new();
        for i in 0..4 {
            let (p, rx) = pending_prio("critmodel", 1, Priority::Critical, 10 + i, &clock);
            q.push(p).map_err(|_| ()).unwrap();
            _rxs.push(rx);
        }
        let t0 = std::time::Instant::now();
        let batch = q
            .pop_batch(&clock, policy(200, 4, 16), Duration::from_millis(500))
            .unwrap();
        assert!(batch.iter().all(|p| p.model == "critmodel"), "bulk window won");
        assert_eq!(batch.len(), 4);
        assert!(t0.elapsed() < Duration::from_millis(100), "waited on bulk's window");
        assert_eq!(q.preemptions(), 1, "preemption not counted");
    }

    #[test]
    fn shed_from_bulk_admits_critical_when_full() {
        let clock = Clock::real();
        let q = BatchQueue::new(4);
        let mut _rxs = Vec::new();
        for i in 0..4 {
            let (p, rx) = pending_prio("m", 1, Priority::Bulk, i, &clock);
            q.push(p).map_err(|_| ()).unwrap();
            _rxs.push(rx);
        }
        assert_eq!(q.rows_queued(), 4);
        // Queue full of bulk: a critical push evicts the NEWEST bulk
        // request instead of being rejected at ingress.
        let (pc, _rc) = pending_prio("m", 1, Priority::Critical, 99, &clock);
        let evicted = q.push(pc).expect("critical rejected while bulk queued");
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].priority, Priority::Bulk);
        assert_eq!(evicted[0].trace_id, 3, "evicted an older bulk request, not the newest");
        assert_eq!(q.rows_queued(), 4);
        assert_eq!(q.priority_depths(), [3, 0, 1]);
    }

    #[test]
    fn shed_evicts_multiple_bulk_rows_for_wide_critical() {
        let clock = Clock::real();
        let q = BatchQueue::new(8);
        let mut _rxs = Vec::new();
        for i in 0..4 {
            let (p, rx) = pending_prio("m", 2, Priority::Bulk, i, &clock);
            q.push(p).map_err(|_| ()).unwrap();
            _rxs.push(rx);
        }
        // 4-row critical needs two 2-row bulk evictions; newest first.
        let (pc, _rc) = pending_prio("m", 4, Priority::Critical, 99, &clock);
        let evicted = q.push(pc).expect("critical rejected");
        assert_eq!(evicted.len(), 2);
        assert_eq!(evicted[0].trace_id, 3);
        assert_eq!(evicted[1].trace_id, 2);
        assert_eq!(q.rows_queued(), 8);
    }

    #[test]
    fn shed_never_evicts_equal_or_higher_priority() {
        let clock = Clock::real();
        let q = BatchQueue::new(2);
        let (p1, _r1) = pending_prio("m", 1, Priority::Standard, 0, &clock);
        let (p2, _r2) = pending_prio("m", 1, Priority::Critical, 1, &clock);
        q.push(p1).map_err(|_| ()).unwrap();
        q.push(p2).map_err(|_| ()).unwrap();
        // standard incoming: may not evict standard (equal) or critical
        let (p3, _r3) = pending_prio("m", 1, Priority::Standard, 2, &clock);
        assert!(q.push(p3).is_err(), "evicted an equal-or-higher priority request");
        // critical incoming: the standard entry is fair game, not the
        // critical one
        let (p4, _r4) = pending_prio("m", 1, Priority::Critical, 3, &clock);
        let evicted = q.push(p4).expect("critical rejected");
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].priority, Priority::Standard);
        assert_eq!(q.priority_depths(), [0, 0, 2]);
    }

    #[test]
    fn fifo_mode_is_priority_blind() {
        let clock = Clock::real();
        let q = BatchQueue::with_mode(64, BatchMode::Fifo);
        let (pb, _rb) = pending_prio("m", 1, Priority::Bulk, 1, &clock);
        q.push(pb).map_err(|_| ()).unwrap();
        let (pc, _rc) = pending_prio("m", 1, Priority::Critical, 2, &clock);
        q.push(pc).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        // global arrival order: the bulk request is first in the batch
        let batch = q
            .pop_batch(&clock, policy(1, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].trace_id, 1, "fifo reordered by priority");
        assert_eq!(batch[1].trace_id, 2);
        assert_eq!(q.preemptions(), 0);
    }

    #[test]
    fn priority_depth_for_splits_one_models_lanes() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        let mut _rxs = Vec::new();
        for (model, prio) in [
            ("a", Priority::Bulk),
            ("a", Priority::Bulk),
            ("a", Priority::Critical),
            ("b", Priority::Standard),
        ] {
            let (p, rx) = pending_prio(model, 1, prio, 0, &clock);
            q.push(p).map_err(|_| ()).unwrap();
            _rxs.push(rx);
        }
        assert_eq!(q.priority_depth_for("a"), [2, 0, 1]);
        assert_eq!(q.priority_depth_for("b"), [0, 1, 0]);
        assert_eq!(q.priority_depth_for("unknown"), [0, 0, 0]);
    }

    #[test]
    fn aged_bulk_head_promoted_past_expired_critical() {
        let clock = Clock::real();
        let q = BatchQueue::with_aging(64, BatchMode::Affinity, Duration::from_millis(40));
        // Bulk arrives on one model...
        let (pb, _rb) = pending_prio("bulkmodel", 1, Priority::Bulk, 1, &clock);
        q.push(pb).map_err(|_| ()).unwrap();
        // ...and by the time it crosses the aging bound, expired
        // critical work is queued on another model.
        std::thread::sleep(Duration::from_millis(50));
        let (pc, _rc) = pending_prio("critmodel", 1, Priority::Critical, 2, &clock);
        q.push(pc).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        // Without aging the critical lane would win priority-first
        // selection; the aged bulk head must be promoted past it once.
        let batch = q
            .pop_batch(&clock, policy(1, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch[0].trace_id, 1, "aged bulk head not promoted");
        // The promotion is one pop: critical is served right after.
        let batch = q
            .pop_batch(&clock, policy(1, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch[0].trace_id, 2);
    }

    #[test]
    fn aging_disabled_keeps_pure_priority_order() {
        let clock = Clock::real();
        let q = BatchQueue::new(64); // max_bulk_wait zero = off
        let (pb, _rb) = pending_prio("bulkmodel", 1, Priority::Bulk, 1, &clock);
        q.push(pb).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let (pc, _rc) = pending_prio("critmodel", 1, Priority::Critical, 2, &clock);
        q.push(pc).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let batch = q
            .pop_batch(&clock, policy(1, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch[0].trace_id, 2, "critical should win without aging");
    }

    #[test]
    fn aging_wakes_sleeping_pop_at_the_bound() {
        let clock = Clock::real();
        // Wide 5 s batching window, 60 ms aging bound: the pop must wake
        // at the bound, not the window.
        let q = BatchQueue::with_aging(64, BatchMode::Affinity, Duration::from_millis(60));
        let (pb, _rb) = pending_prio("m", 1, Priority::Bulk, 7, &clock);
        q.push(pb).map_err(|_| ()).unwrap();
        let t0 = std::time::Instant::now();
        let batch = q
            .pop_batch(&clock, policy(5000, 8, 16), Duration::from_secs(5))
            .unwrap();
        assert_eq!(batch[0].trace_id, 7);
        let waited = t0.elapsed();
        assert!(
            waited >= Duration::from_millis(50) && waited < Duration::from_millis(500),
            "pop should wake near the aging bound, waited {waited:?}"
        );
    }

    #[test]
    fn popped_requests_record_queue_spans() {
        let clock = Clock::simulated();
        let tracer = Tracer::new(clock.clone(), 64, true);
        let q = BatchQueue::new(64).with_tracer(tracer.clone());
        clock.advance(Duration::from_secs(1));
        let (p, _rx) = pending_prio("m", 1, Priority::Standard, 42, &clock);
        q.push(p).map_err(|_| ()).unwrap();
        clock.advance(Duration::from_secs(2));
        let batch = q
            .pop_batch(&clock, policy(1, 1, 16), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch.len(), 1);
        let v = tracer.trace(42);
        assert_eq!(v.spans.len(), 1);
        assert_eq!(v.spans[0].name, "queue");
        assert!((v.duration_of("queue") - 2.0).abs() < 1e-6, "{}", v.duration_of("queue"));
    }

    #[test]
    fn draining_flush_covers_all_lanes() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        let mut _rxs = Vec::new();
        for (prio, id) in [(Priority::Bulk, 1), (Priority::Critical, 2), (Priority::Standard, 3)]
        {
            let (p, rx) = pending_prio("m", 1, prio, id, &clock);
            q.push(p).map_err(|_| ()).unwrap();
            _rxs.push(rx);
        }
        q.drain();
        let batch = q
            .pop_batch(&clock, policy(5000, 64, 64), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch.len(), 3);
        assert!(q.drained());
    }
}
