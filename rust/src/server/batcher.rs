//! Dynamic batching queue — Triton's "dynamic_batching" policy (§2.1),
//! with model-affinity admission.
//!
//! Requests land in a per-instance [`BatchQueue`] that keeps one
//! sub-queue per model (the per-(instance, model) admission groups), so
//! a popped batch never interleaves models and a model's backlog is
//! directly observable ([`BatchQueue::depth_for`] — the signal the
//! placement controller folds into its demand estimate).
//!
//! How the executor picks *which* model to serve is the
//! [`BatchMode`](crate::config::BatchMode):
//!
//! * **`Affinity`** (default): serve any model whose head request has
//!   outlived its batching window (deadline order, oldest first), else
//!   any model whose accumulated rows reached the preferred batch (most
//!   rows first), else sleep until the earliest deadline. A cold model's
//!   half-empty window never blocks a hot model's ready batch.
//! * **`Fifo`**: always serve the model of the globally oldest request,
//!   waiting out that model's window first — strict arrival order, the
//!   pre-affinity behavior, kept as the `warm_load_ablation` baseline.
//!
//! Within a model, requests are always served in arrival order, and both
//! modes flush a head request no later than its `max_queue_delay`.
//!
//! The queue is also where overload protection lands: pushes beyond
//! `capacity` (summed across models) are rejected so the gateway can
//! shed load with an `Overloaded` status instead of building unbounded
//! latency (§2.2).

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::config::BatchMode;
use crate::rpc::codec::Status;
use crate::runtime::Tensor;
use crate::util::clock::{Clock, Nanos};

/// Batching knobs for one model (from `config::ModelConfig`).
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Hold the head request at most this long while accumulating.
    pub max_queue_delay: Duration,
    /// Stop accumulating at this many rows.
    pub preferred_rows: usize,
    /// Hard cap on rows per popped batch — the model's largest compiled
    /// engine batch (Triton's `max_batch_size`). Folding beyond it would
    /// only chain engine calls serially while hiding per-request queue
    /// time from the autoscaler trigger.
    pub max_rows: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_queue_delay: Duration::from_millis(2),
            preferred_rows: 8,
            max_rows: 64,
        }
    }
}

/// Executor's reply to one queued request.
#[derive(Debug)]
pub enum ExecOutcome {
    Ok {
        output: Tensor,
        queue_us: u32,
        compute_us: u32,
        batch_rows: u32,
    },
    Err {
        status: Status,
        message: String,
    },
}

/// One queued request.
pub struct Pending {
    pub model: String,
    pub input: Tensor,
    pub enqueued: Nanos,
    pub trace_id: u64,
    pub reply: mpsc::Sender<ExecOutcome>,
}

impl Pending {
    /// Rows this request contributes to a batch.
    pub fn rows(&self) -> usize {
        self.input.batch()
    }
}

/// One model's admission group: requests in arrival order, tagged with a
/// queue-global sequence number so `Fifo` mode can reconstruct the
/// global arrival order across groups.
struct Group {
    queue: VecDeque<(u64, Pending)>,
    rows: usize,
}

struct Inner {
    groups: BTreeMap<String, Group>,
    /// Total queued requests across groups (the capacity bound).
    len: usize,
    next_seq: u64,
    draining: bool,
}

/// What the selection pass decided to do.
enum Pick {
    /// Serve this model now.
    Serve(String),
    /// Nothing servable yet; earliest head deadline in clock nanos.
    WaitUntil(Nanos),
}

/// Bounded, condvar-signalled batch queue with per-model groups.
pub struct BatchQueue {
    inner: Mutex<Inner>,
    available: Condvar,
    capacity: usize,
    mode: BatchMode,
}

impl BatchQueue {
    /// Queue holding at most `capacity` requests, with the default
    /// model-affinity admission.
    pub fn new(capacity: usize) -> Self {
        Self::with_mode(capacity, BatchMode::Affinity)
    }

    /// Queue with an explicit admission mode (`Fifo` is the ablation
    /// baseline).
    pub fn with_mode(capacity: usize, mode: BatchMode) -> Self {
        BatchQueue {
            inner: Mutex::new(Inner {
                groups: BTreeMap::new(),
                len: 0,
                next_seq: 0,
                draining: false,
            }),
            available: Condvar::new(),
            capacity,
            mode,
        }
    }

    /// Enqueue a request. Fails fast when full or draining.
    pub fn push(&self, pending: Pending) -> Result<(), Pending> {
        let mut inner = self.inner.lock().unwrap();
        if inner.draining || inner.len >= self.capacity {
            return Err(pending);
        }
        let seq = inner.next_seq;
        inner.next_seq += 1;
        inner.len += 1;
        let rows = pending.rows();
        let group = inner
            .groups
            .entry(pending.model.clone())
            .or_insert_with(|| Group { queue: VecDeque::new(), rows: 0 });
        group.rows += rows;
        group.queue.push_back((seq, pending));
        self.available.notify_one();
        Ok(())
    }

    /// Current queue depth (requests, all models).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().len
    }

    /// Queued requests for one model — the per-model backlog the
    /// placement demand signal consumes.
    pub fn depth_for(&self, model: &str) -> usize {
        self.inner
            .lock()
            .unwrap()
            .groups
            .get(model)
            .map(|g| g.queue.len())
            .unwrap_or(0)
    }

    /// Per-model depth snapshot under a single lock acquisition (the
    /// executor's gauge refresh — one `depth_for` per model would take
    /// the hot-path mutex once per model per wakeup).
    pub fn depths(&self) -> Vec<(String, usize)> {
        self.inner
            .lock()
            .unwrap()
            .groups
            .iter()
            .map(|(m, g)| (m.clone(), g.queue.len()))
            .collect()
    }

    /// Mark draining: pushes fail, pops continue until empty.
    pub fn drain(&self) {
        self.inner.lock().unwrap().draining = true;
        self.available.notify_all();
    }

    /// True once draining and empty.
    pub fn drained(&self) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.draining && inner.len == 0
    }

    /// Decide which model to serve, or how long to wait. `Draining`
    /// flushes everything immediately (oldest head first).
    fn select<F>(&self, inner: &Inner, now: Nanos, policy_for: &F) -> Pick
    where
        F: Fn(&str) -> BatchPolicy,
    {
        if self.mode == BatchMode::Fifo && !inner.draining {
            // Global arrival order: the model of the oldest request, held
            // until its own target/deadline (head-of-line semantics).
            let (model, head) = inner
                .groups
                .iter()
                .filter_map(|(m, g)| g.queue.front().map(|(seq, p)| (m, (*seq, p.enqueued))))
                .min_by_key(|&(_, (seq, _))| seq)
                .map(|(m, (_, enq))| (m.clone(), enq))
                .expect("select called with requests queued");
            let policy = policy_for(&model);
            let group = &inner.groups[&model];
            let target = policy.preferred_rows.min(policy.max_rows).max(1);
            let deadline = head + policy.max_queue_delay.as_nanos() as Nanos;
            if group.rows >= target || now >= deadline {
                return Pick::Serve(model);
            }
            return Pick::WaitUntil(deadline);
        }

        // Affinity (and any draining flush): deadline-expired heads
        // first, oldest head first — the latency bound holds per model.
        let mut expired: Option<(Nanos, String)> = None;
        let mut ready: Option<(usize, String)> = None;
        let mut earliest: Option<Nanos> = None;
        for (model, group) in &inner.groups {
            let Some((_, head)) = group.queue.front() else { continue };
            let policy = policy_for(model);
            let target = policy.preferred_rows.min(policy.max_rows).max(1);
            let deadline = head.enqueued + policy.max_queue_delay.as_nanos() as Nanos;
            if inner.draining || now >= deadline {
                if expired.as_ref().is_none_or(|(e, _)| head.enqueued < *e) {
                    expired = Some((head.enqueued, model.clone()));
                }
            } else if group.rows >= target {
                if ready.as_ref().is_none_or(|(r, _)| group.rows > *r) {
                    ready = Some((group.rows, model.clone()));
                }
            } else if earliest.as_ref().is_none_or(|e| deadline < *e) {
                earliest = Some(deadline);
            }
        }
        if let Some((_, model)) = expired {
            return Pick::Serve(model);
        }
        if let Some((_, model)) = ready {
            return Pick::Serve(model);
        }
        Pick::WaitUntil(earliest.expect("some non-empty group has no pick"))
    }

    /// Pop one same-model batch according to `policy_for` and the
    /// queue's [`BatchMode`].
    ///
    /// Blocks up to `idle_timeout` waiting for a first request; returns
    /// `None` on timeout (the executor uses idle wakeups to refresh
    /// utilization gauges) or when draining and empty.
    ///
    /// The policy's `max_rows` caps the batch at the largest compiled
    /// engine batch. A single over-large request is returned alone (the
    /// executor splits it across engine calls).
    pub fn pop_batch<F>(
        &self,
        clock: &Clock,
        policy_for: F,
        idle_timeout: Duration,
    ) -> Option<Vec<Pending>>
    where
        F: Fn(&str) -> BatchPolicy,
    {
        let mut inner = self.inner.lock().unwrap();

        // Phase 1: wait for a first request.
        let wait_start = std::time::Instant::now();
        while inner.len == 0 {
            if inner.draining {
                return None;
            }
            let remaining = idle_timeout.checked_sub(wait_start.elapsed())?;
            let (guard, timeout) = self
                .available
                .wait_timeout(inner, remaining.min(Duration::from_millis(50)))
                .unwrap();
            inner = guard;
            if timeout.timed_out()
                && wait_start.elapsed() >= idle_timeout
                && inner.len == 0
            {
                return None;
            }
        }

        // Phase 2: pick a model, waiting out batching windows as the
        // mode dictates. New pushes re-run the selection.
        let model = loop {
            if inner.len == 0 {
                // Drained out from under us (defensive: single-consumer
                // queues cannot shrink here, but the contract allows it).
                if inner.draining {
                    return None;
                }
                let (guard, _) = self
                    .available
                    .wait_timeout(inner, Duration::from_millis(20))
                    .unwrap();
                inner = guard;
                continue;
            }
            let now = clock.now();
            match self.select(&inner, now, &policy_for) {
                Pick::Serve(model) => break model,
                Pick::WaitUntil(deadline) => {
                    // Convert the *clock-time* deadline into a bounded
                    // real-time wait; the cap re-checks under dilation.
                    let clock_remaining = Duration::from_nanos(deadline.saturating_sub(now));
                    let wait = clock_remaining.min(Duration::from_millis(20));
                    let (guard, _) = self.available.wait_timeout(inner, wait).unwrap();
                    inner = guard;
                }
            }
        };

        // Phase 3: pop the model's requests in arrival order up to the
        // row budget. An oversized head goes alone.
        let policy = policy_for(&model);
        let max_rows = policy.max_rows.max(1);
        let group = inner.groups.get_mut(&model).expect("selected group exists");
        let mut batch = Vec::new();
        let mut rows = 0usize;
        while let Some((_, p)) = group.queue.front() {
            let r = p.rows();
            if batch.is_empty() && r > max_rows {
                batch.push(group.queue.pop_front().unwrap().1);
                rows += r;
                break;
            }
            if rows + r > max_rows {
                break;
            }
            rows += r;
            batch.push(group.queue.pop_front().unwrap().1);
        }
        group.rows -= rows.min(group.rows);
        if group.queue.is_empty() {
            inner.groups.remove(&model);
        }
        inner.len -= batch.len();
        // The selected group always has a head and the first iteration
        // always takes it (an oversized head goes alone), so a selected
        // pop can never come back empty.
        debug_assert!(!batch.is_empty());
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn pending(model: &str, rows: usize, clock: &Clock) -> (Pending, mpsc::Receiver<ExecOutcome>) {
        let (tx, rx) = mpsc::channel();
        let shape = vec![rows, 2];
        (
            Pending {
                model: model.into(),
                input: Tensor::zeros(shape),
                enqueued: clock.now(),
                trace_id: 0,
                reply: tx,
            },
            rx,
        )
    }

    fn policy(delay_ms: u64, rows: usize, max_rows: usize) -> impl Fn(&str) -> BatchPolicy {
        move |_| BatchPolicy {
            max_queue_delay: Duration::from_millis(delay_ms),
            preferred_rows: rows,
            max_rows,
        }
    }

    #[test]
    fn pops_immediately_at_preferred_rows() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        for _ in 0..4 {
            let (p, _rx) = pending("m", 2, &clock);
            q.push(p).map_err(|_| ()).unwrap();
        }
        let batch = q
            .pop_batch(&clock, policy(1000, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.iter().map(|p| p.rows()).sum::<usize>(), 8);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        let (p, _rx) = pending("m", 1, &clock);
        q.push(p).map_err(|_| ()).unwrap();
        let t0 = std::time::Instant::now();
        let batch = q
            .pop_batch(&clock, policy(30, 8, 16), Duration::from_millis(500))
            .unwrap();
        assert_eq!(batch.len(), 1);
        // must have waited ~the queue delay, not the idle timeout
        assert!(t0.elapsed() < Duration::from_millis(300));
    }

    #[test]
    fn same_model_runs_only() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        let (pa, _r1) = pending("a", 1, &clock);
        let (pb, _r2) = pending("b", 1, &clock);
        let (pa2, _r3) = pending("a", 1, &clock);
        q.push(pa).map_err(|_| ()).unwrap();
        q.push(pb).map_err(|_| ()).unwrap();
        q.push(pa2).map_err(|_| ()).unwrap();
        let batch = q
            .pop_batch(&clock, policy(5, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|p| p.model == "a"));
        assert_eq!(q.depth(), 1); // "b" stays
        assert_eq!(q.depth_for("b"), 1);
        assert_eq!(q.depth_for("a"), 0);
    }

    #[test]
    fn row_budget_respected() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        for _ in 0..5 {
            let (p, _rx) = pending("m", 4, &clock);
            q.push(p).map_err(|_| ()).unwrap();
        }
        let batch = q
            .pop_batch(&clock, policy(5, 100, 10), Duration::from_millis(100))
            .unwrap();
        // 4+4 = 8 fits; adding the third (12 > 10) does not.
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn oversized_request_pops_alone() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        let (p, _rx) = pending("m", 100, &clock);
        q.push(p).map_err(|_| ()).unwrap();
        let (p2, _rx2) = pending("m", 1, &clock);
        q.push(p2).map_err(|_| ()).unwrap();
        let batch = q
            .pop_batch(&clock, policy(5, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].rows(), 100);
    }

    #[test]
    fn capacity_rejects() {
        let clock = Clock::real();
        let q = BatchQueue::new(2);
        let (p1, _r1) = pending("m", 1, &clock);
        let (p2, _r2) = pending("m", 1, &clock);
        let (p3, _r3) = pending("m", 1, &clock);
        assert!(q.push(p1).is_ok());
        assert!(q.push(p2).is_ok());
        assert!(q.push(p3).is_err());
    }

    #[test]
    fn drain_rejects_pushes_and_unblocks() {
        let clock = Clock::real();
        let q = Arc::new(BatchQueue::new(8));
        q.drain();
        let (p, _rx) = pending("m", 1, &clock);
        assert!(q.push(p).is_err());
        assert!(q
            .pop_batch(&clock, policy(5, 8, 16), Duration::from_millis(50))
            .is_none());
        assert!(q.drained());
    }

    #[test]
    fn drain_flushes_queued_requests() {
        let clock = Clock::real();
        let q = BatchQueue::new(8);
        let (p, _rx) = pending("m", 1, &clock);
        q.push(p).map_err(|_| ()).unwrap();
        q.drain();
        // long window, but draining flushes immediately
        let t0 = std::time::Instant::now();
        let batch = q
            .pop_batch(&clock, policy(5000, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(100));
        assert!(q.drained());
    }

    #[test]
    fn idle_timeout_returns_none() {
        let clock = Clock::real();
        let q = BatchQueue::new(8);
        let t0 = std::time::Instant::now();
        assert!(q
            .pop_batch(&clock, policy(5, 8, 16), Duration::from_millis(40))
            .is_none());
        assert!(t0.elapsed() >= Duration::from_millis(35));
    }

    #[test]
    fn push_wakes_blocked_pop() {
        let clock = Clock::real();
        let q = Arc::new(BatchQueue::new(8));
        let q2 = Arc::clone(&q);
        let c2 = clock.clone();
        let h = std::thread::spawn(move || {
            q2.pop_batch(&c2, policy(1, 1, 16), Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(20));
        let (p, _rx) = pending("m", 1, &clock);
        q.push(p).map_err(|_| ()).unwrap();
        let batch = h.join().unwrap().unwrap();
        assert_eq!(batch.len(), 1);
    }

    /// Per-model policies for the affinity-vs-fifo pair below: the cold
    /// model holds a wide batching window, the hot model a narrow one.
    fn mixed_policy(model: &str) -> BatchPolicy {
        match model {
            "cold" => BatchPolicy {
                max_queue_delay: Duration::from_millis(120),
                preferred_rows: 8,
                max_rows: 16,
            },
            _ => BatchPolicy {
                max_queue_delay: Duration::from_millis(120),
                preferred_rows: 4,
                max_rows: 16,
            },
        }
    }

    #[test]
    fn affinity_serves_ready_model_past_blocked_head() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        // cold arrives first (the queue head) but never fills its batch
        let (pc, _rc) = pending("cold", 1, &clock);
        q.push(pc).map_err(|_| ()).unwrap();
        let mut _rxs = Vec::new();
        for _ in 0..4 {
            let (p, rx) = pending("hot", 1, &clock);
            q.push(p).map_err(|_| ()).unwrap();
            _rxs.push(rx);
        }
        // hot reached its preferred rows: affinity serves it immediately,
        // long before cold's 120 ms window expires
        let t0 = std::time::Instant::now();
        let batch = q
            .pop_batch(&clock, mixed_policy, Duration::from_millis(500))
            .unwrap();
        assert!(batch.iter().all(|p| p.model == "hot"), "served the blocked head first");
        assert_eq!(batch.len(), 4);
        assert!(t0.elapsed() < Duration::from_millis(60), "waited on cold's window");
        // cold still flushes by its own deadline
        let batch = q
            .pop_batch(&clock, mixed_policy, Duration::from_millis(500))
            .unwrap();
        assert!(batch.iter().all(|p| p.model == "cold"));
    }

    #[test]
    fn fifo_head_of_line_blocks_ready_model() {
        let clock = Clock::real();
        let q = BatchQueue::with_mode(64, BatchMode::Fifo);
        let (pc, _rc) = pending("cold", 1, &clock);
        q.push(pc).map_err(|_| ()).unwrap();
        for _ in 0..4 {
            let (p, _rx) = pending("hot", 1, &clock);
            q.push(p).map_err(|_| ()).unwrap();
        }
        // strict arrival order: cold is served first, after waiting out
        // its full batching window, even though hot has a ready batch
        let t0 = std::time::Instant::now();
        let batch = q
            .pop_batch(&clock, mixed_policy, Duration::from_millis(500))
            .unwrap();
        assert!(batch.iter().all(|p| p.model == "cold"));
        assert!(
            t0.elapsed() >= Duration::from_millis(100),
            "fifo did not wait out the head's window: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn affinity_expired_heads_flush_oldest_first() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        let (pa, _ra) = pending("a", 1, &clock);
        q.push(pa).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let (pb, _rb) = pending("b", 1, &clock);
        q.push(pb).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        // both expired (1 ms windows): oldest head ("a") first
        let batch = q
            .pop_batch(&clock, policy(1, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert!(batch.iter().all(|p| p.model == "a"));
        let batch = q
            .pop_batch(&clock, policy(1, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert!(batch.iter().all(|p| p.model == "b"));
    }
}
