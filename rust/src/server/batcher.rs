//! Dynamic batching queue — Triton's "dynamic_batching" policy (§2.1).
//!
//! Requests land in a per-instance [`BatchQueue`]; the instance's executor
//! pops *same-model runs*: it waits until either the accumulated rows for
//! the model at the head of the queue reach the preferred batch size, or
//! the head request has been queued for the model's max queue delay —
//! whichever comes first — and then takes every queued request for that
//! model (in arrival order) that fits the row budget.
//!
//! The queue is also where overload protection lands: pushes beyond
//! `capacity` are rejected so the gateway can shed load with an
//! `Overloaded` status instead of building unbounded latency (§2.2).

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::rpc::codec::Status;
use crate::runtime::Tensor;
use crate::util::clock::{Clock, Nanos};

/// Batching knobs for one model (from `config::ModelConfig`).
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Hold the head request at most this long while accumulating.
    pub max_queue_delay: Duration,
    /// Stop accumulating at this many rows.
    pub preferred_rows: usize,
    /// Hard cap on rows per popped batch — the model's largest compiled
    /// engine batch (Triton's `max_batch_size`). Folding beyond it would
    /// only chain engine calls serially while hiding per-request queue
    /// time from the autoscaler trigger.
    pub max_rows: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_queue_delay: Duration::from_millis(2),
            preferred_rows: 8,
            max_rows: 64,
        }
    }
}

/// Executor's reply to one queued request.
#[derive(Debug)]
pub enum ExecOutcome {
    Ok {
        output: Tensor,
        queue_us: u32,
        compute_us: u32,
        batch_rows: u32,
    },
    Err {
        status: Status,
        message: String,
    },
}

/// One queued request.
pub struct Pending {
    pub model: String,
    pub input: Tensor,
    pub enqueued: Nanos,
    pub trace_id: u64,
    pub reply: mpsc::Sender<ExecOutcome>,
}

impl Pending {
    /// Rows this request contributes to a batch.
    pub fn rows(&self) -> usize {
        self.input.batch()
    }
}

struct Inner {
    queue: VecDeque<Pending>,
    draining: bool,
}

/// Bounded, condvar-signalled batch queue.
pub struct BatchQueue {
    inner: Mutex<Inner>,
    available: Condvar,
    capacity: usize,
}

impl BatchQueue {
    /// Queue holding at most `capacity` requests.
    pub fn new(capacity: usize) -> Self {
        BatchQueue {
            inner: Mutex::new(Inner { queue: VecDeque::new(), draining: false }),
            available: Condvar::new(),
            capacity,
        }
    }

    /// Enqueue a request. Fails fast when full or draining.
    pub fn push(&self, pending: Pending) -> Result<(), Pending> {
        let mut inner = self.inner.lock().unwrap();
        if inner.draining || inner.queue.len() >= self.capacity {
            return Err(pending);
        }
        inner.queue.push_back(pending);
        self.available.notify_one();
        Ok(())
    }

    /// Current queue depth (requests).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Mark draining: pushes fail, pops continue until empty.
    pub fn drain(&self) {
        self.inner.lock().unwrap().draining = true;
        self.available.notify_all();
    }

    /// True once draining and empty.
    pub fn drained(&self) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.draining && inner.queue.is_empty()
    }

    /// Pop one same-model batch according to `policy_for`.
    ///
    /// Blocks up to `idle_timeout` waiting for a first request; returns
    /// `None` on timeout (the executor uses idle wakeups to refresh
    /// utilization gauges) or when draining and empty.
    ///
    /// The policy's `max_rows` caps the batch at the largest compiled
    /// engine batch. A single over-large request is returned alone (the
    /// executor splits it across engine calls).
    pub fn pop_batch<F>(
        &self,
        clock: &Clock,
        policy_for: F,
        idle_timeout: Duration,
    ) -> Option<Vec<Pending>>
    where
        F: Fn(&str) -> BatchPolicy,
    {
        let mut inner = self.inner.lock().unwrap();

        // Phase 1: wait for a head request.
        let wait_start = std::time::Instant::now();
        while inner.queue.is_empty() {
            if inner.draining {
                return None;
            }
            let remaining = idle_timeout.checked_sub(wait_start.elapsed())?;
            let (guard, timeout) = self
                .available
                .wait_timeout(inner, remaining.min(Duration::from_millis(50)))
                .unwrap();
            inner = guard;
            if timeout.timed_out() && wait_start.elapsed() >= idle_timeout {
                if inner.queue.is_empty() {
                    return None;
                }
            }
        }

        let model = inner.queue[0].model.clone();
        let head_enqueued = inner.queue[0].enqueued;
        let policy = policy_for(&model);
        let max_rows = policy.max_rows.max(1);
        let target_rows = policy.preferred_rows.min(max_rows).max(1);
        let deadline = head_enqueued + policy.max_queue_delay.as_nanos() as Nanos;

        // Phase 2: accumulate same-model rows until target or deadline.
        loop {
            let rows: usize = inner
                .queue
                .iter()
                .filter(|p| p.model == model)
                .map(|p| p.rows())
                .sum();
            let now = clock.now();
            if rows >= target_rows || now >= deadline || inner.draining {
                break;
            }
            // Convert the *clock-time* deadline into a real-time wait.
            let clock_remaining = Duration::from_nanos(deadline - now);
            let wait = clock_remaining.min(Duration::from_millis(20));
            let (guard, _) = self.available.wait_timeout(inner, wait).unwrap();
            inner = guard;
            if inner.queue.is_empty() {
                // Drained out from under us.
                if inner.draining {
                    return None;
                }
                continue;
            }
        }

        // Phase 3: pop every same-model request that fits the row budget,
        // in arrival order. An oversized head goes alone.
        let mut batch = Vec::new();
        let mut rows = 0usize;
        let mut i = 0;
        while i < inner.queue.len() {
            if inner.queue[i].model != model {
                i += 1;
                continue;
            }
            let r = inner.queue[i].rows();
            if batch.is_empty() && r > max_rows {
                batch.push(inner.queue.remove(i).unwrap());
                break;
            }
            if rows + r > max_rows {
                break;
            }
            rows += r;
            batch.push(inner.queue.remove(i).unwrap());
        }
        if batch.is_empty() {
            return None;
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn pending(model: &str, rows: usize, clock: &Clock) -> (Pending, mpsc::Receiver<ExecOutcome>) {
        let (tx, rx) = mpsc::channel();
        let shape = vec![rows, 2];
        (
            Pending {
                model: model.into(),
                input: Tensor::zeros(shape),
                enqueued: clock.now(),
                trace_id: 0,
                reply: tx,
            },
            rx,
        )
    }

    fn policy(delay_ms: u64, rows: usize, max_rows: usize) -> impl Fn(&str) -> BatchPolicy {
        move |_| BatchPolicy {
            max_queue_delay: Duration::from_millis(delay_ms),
            preferred_rows: rows,
            max_rows,
        }
    }

    #[test]
    fn pops_immediately_at_preferred_rows() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        for _ in 0..4 {
            let (p, _rx) = pending("m", 2, &clock);
            q.push(p).map_err(|_| ()).unwrap();
        }
        let batch = q
            .pop_batch(&clock, policy(1000, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.iter().map(|p| p.rows()).sum::<usize>(), 8);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        let (p, _rx) = pending("m", 1, &clock);
        q.push(p).map_err(|_| ()).unwrap();
        let t0 = std::time::Instant::now();
        let batch = q
            .pop_batch(&clock, policy(30, 8, 16), Duration::from_millis(500))
            .unwrap();
        assert_eq!(batch.len(), 1);
        // must have waited ~the queue delay, not the idle timeout
        assert!(t0.elapsed() < Duration::from_millis(300));
    }

    #[test]
    fn same_model_runs_only() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        let (pa, _r1) = pending("a", 1, &clock);
        let (pb, _r2) = pending("b", 1, &clock);
        let (pa2, _r3) = pending("a", 1, &clock);
        q.push(pa).map_err(|_| ()).unwrap();
        q.push(pb).map_err(|_| ()).unwrap();
        q.push(pa2).map_err(|_| ()).unwrap();
        let batch = q
            .pop_batch(&clock, policy(5, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|p| p.model == "a"));
        assert_eq!(q.depth(), 1); // "b" stays
    }

    #[test]
    fn row_budget_respected() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        for _ in 0..5 {
            let (p, _rx) = pending("m", 4, &clock);
            q.push(p).map_err(|_| ()).unwrap();
        }
        let batch = q
            .pop_batch(&clock, policy(5, 100, 10), Duration::from_millis(100))
            .unwrap();
        // 4+4 = 8 fits; adding the third (12 > 10) does not.
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn oversized_request_pops_alone() {
        let clock = Clock::real();
        let q = BatchQueue::new(64);
        let (p, _rx) = pending("m", 100, &clock);
        q.push(p).map_err(|_| ()).unwrap();
        let (p2, _rx2) = pending("m", 1, &clock);
        q.push(p2).map_err(|_| ()).unwrap();
        let batch = q
            .pop_batch(&clock, policy(5, 8, 16), Duration::from_millis(100))
            .unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].rows(), 100);
    }

    #[test]
    fn capacity_rejects() {
        let clock = Clock::real();
        let q = BatchQueue::new(2);
        let (p1, _r1) = pending("m", 1, &clock);
        let (p2, _r2) = pending("m", 1, &clock);
        let (p3, _r3) = pending("m", 1, &clock);
        assert!(q.push(p1).is_ok());
        assert!(q.push(p2).is_ok());
        assert!(q.push(p3).is_err());
    }

    #[test]
    fn drain_rejects_pushes_and_unblocks() {
        let clock = Clock::real();
        let q = Arc::new(BatchQueue::new(8));
        q.drain();
        let (p, _rx) = pending("m", 1, &clock);
        assert!(q.push(p).is_err());
        assert!(q
            .pop_batch(&clock, policy(5, 8, 16), Duration::from_millis(50))
            .is_none());
        assert!(q.drained());
    }

    #[test]
    fn idle_timeout_returns_none() {
        let clock = Clock::real();
        let q = BatchQueue::new(8);
        let t0 = std::time::Instant::now();
        assert!(q
            .pop_batch(&clock, policy(5, 8, 16), Duration::from_millis(40))
            .is_none());
        assert!(t0.elapsed() >= Duration::from_millis(35));
    }

    #[test]
    fn push_wakes_blocked_pop() {
        let clock = Clock::real();
        let q = Arc::new(BatchQueue::new(8));
        let q2 = Arc::clone(&q);
        let c2 = clock.clone();
        let h = std::thread::spawn(move || {
            q2.pop_batch(&c2, policy(1, 1, 16), Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(20));
        let (p, _rx) = pending("m", 1, &clock);
        q.push(p).map_err(|_| ()).unwrap();
        let batch = h.join().unwrap().unwrap();
        assert_eq!(batch.len(), 1);
    }
}
