//! Inference server — the NVIDIA Triton analogue (§2.1).
//!
//! * [`repository`] — the model repository: scans `artifacts/`, parses each
//!   model's `config.yaml`, and compiles every batch-size variant through
//!   the PJRT runtime (CVMFS/NFS/PVC in the paper; a directory here).
//! * [`batcher`] — dynamic batching: requests queue per instance and are
//!   folded into the largest batch available within the configured queue
//!   delay, padded to the nearest compiled batch size.
//! * [`instance`] — one simulated GPU server (a Triton pod): a serialized
//!   executor thread with busy-time (utilization) accounting and queue
//!   latency metrics. The gateway load-balances across Ready instances and
//!   the autoscaler starts/stops them through the orchestrator.

pub mod batcher;
pub mod instance;
pub mod repository;

pub use instance::{Instance, InstanceOptions, InstanceState};
pub use repository::{split_version, versioned_name, ModelEntry, ModelRepository};
