//! Staged canary weight ramps.
//!
//! A canary that jumps straight to its target weight exposes that much
//! traffic to a bad version at once. With `server.models[].canary.ramp`
//! configured (e.g. `[0.01, 0.1, 0.5]`), the split instead starts at the
//! first stage and a [`RampTask`] advances it one stage per
//! `canary.ramp_interval` — but only while the auto-rollback evaluator
//! ([`RollbackEngine`](crate::telemetry::rollback::RollbackEngine))
//! stays quiet for the model. A rollback (or promotion, or any operator
//! action that tears the split down) halts the ramp where it stands;
//! the blast radius of a regressing canary is whatever stage it had
//! earned, not the final weight.
//!
//! [`next_stage`] is the pure advancement rule; [`RampTask`] is the
//! clock loop. In federated mode one task advances the split on every
//! site's router in lock-step (same stages, same hash seed), keeping
//! the version split consistent across sites.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::metrics::registry::{labels, Gauge, Registry};
use crate::modelmesh::ModelRouter;
use crate::telemetry::flight::{DecisionEvent, LoopTicker, RecorderHandle};
use crate::telemetry::rollback::RollbackEngine;
use crate::util::clock::Clock;

/// The next ramp stage strictly above `current`, or `None` when the
/// ramp is exhausted (the split holds at its final stage until promoted
/// or rolled back).
pub fn next_stage(ramp: &[f64], current: f64) -> Option<f64> {
    ramp.iter().copied().find(|w| *w > current + 1e-12)
}

/// The running ramp loop for one model's canary split.
pub struct RampTask {
    stop: Arc<AtomicBool>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    recorder: RecorderHandle,
}

impl RampTask {
    /// Advance `base`'s canary split through `ramp` every `interval` of
    /// clock time, starting from `start_weight`. Each advance re-installs
    /// the split on every router in `routers` with the same `seed`. The
    /// ramp halts permanently when the rollback engine has fired for
    /// `base`, when the split is no longer live (promoted / rolled back /
    /// replaced), or when the final stage is reached.
    #[allow(clippy::too_many_arguments)]
    pub fn start(
        routers: Vec<Arc<ModelRouter>>,
        base: String,
        incumbent: String,
        canary: String,
        ramp: Vec<f64>,
        interval: Duration,
        start_weight: f64,
        seed: u64,
        rollback: Option<Arc<RollbackEngine>>,
        clock: Clock,
        registry: &Registry,
    ) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let gauge: Gauge = registry.gauge("canary_ramp_weight", &labels(&[("model", &base)]));
        gauge.set(start_weight);
        let recorder = RecorderHandle::default();
        let rec = recorder.clone();
        let ticker = LoopTicker::new(registry, clock.clone(), "ramp");
        let handle = std::thread::Builder::new()
            .name("canary-ramp".into())
            .spawn(move || {
                let mut current = start_weight;
                loop {
                    clock.sleep(interval);
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    let advanced = ticker.tick(|| {
                        if let Some(rb) = &rollback {
                            if rb.rolled_back(&base) {
                                log::warn!(
                                    "canary ramp: '{base}' rolled back, halting at {current}"
                                );
                                return None;
                            }
                        }
                        // The policy router (index 0) is the split of
                        // record; a torn-down or replaced split ends the
                        // ramp.
                        let live = routers[0]
                            .canary_of(&base)
                            .map(|(_, c, _)| c == canary)
                            .unwrap_or(false);
                        if !live {
                            return None;
                        }
                        let Some(next) = next_stage(&ramp, current) else {
                            log::info!("canary ramp: '{base}' complete at weight {current}");
                            return None;
                        };
                        for r in &routers {
                            r.set_canary(&base, &incumbent, &canary, next, seed);
                        }
                        gauge.set(next);
                        log::info!("canary ramp: '{base}' {current} -> {next}");
                        rec.record(
                            DecisionEvent::new("ramp", "ramp_advance")
                                .model(&base)
                                .version(&canary)
                                .input("from", current)
                                .input("to", next)
                                .action(format!("canary '{canary}' weight {current} -> {next}")),
                        );
                        Some(next)
                    });
                    match advanced {
                        Some(next) => current = next,
                        None => break,
                    }
                }
            })
            .expect("spawning canary ramp");
        RampTask { stop, handle: Mutex::new(Some(handle)), recorder }
    }

    /// The flight-recorder slot ramp advances land in (installed by the
    /// deployment once the recorder exists).
    pub fn recorder(&self) -> &RecorderHandle {
        &self.recorder
    }

    /// Stop the loop.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_stage_walks_the_ramp() {
        let ramp = [0.01, 0.1, 0.5];
        assert_eq!(next_stage(&ramp, 0.01), Some(0.1));
        assert_eq!(next_stage(&ramp, 0.1), Some(0.5));
        assert_eq!(next_stage(&ramp, 0.5), None);
        // A weight between stages advances to the next strictly above.
        assert_eq!(next_stage(&ramp, 0.05), Some(0.1));
        // Starting below the first stage enters the ramp.
        assert_eq!(next_stage(&ramp, 0.0), Some(0.01));
    }

    #[test]
    fn next_stage_is_float_tolerant() {
        // 0.1 reconstructed through arithmetic must not re-match itself.
        let ramp = [0.1, 0.5];
        let current = 0.3 - 0.2; // 0.09999999999999998, within 1e-12 of 0.1
        assert_eq!(next_stage(&ramp, current), Some(0.5));
    }
}
