//! Dynamic model placement and model-aware routing — the "modelmesh".
//!
//! The base SuperSONIC deployment is all-models-everywhere: one global
//! load balancer over all Triton instances, every instance serving every
//! model in the repository. The dynamic-model-loading follow-up work
//! replaces that with:
//!
//! * **per-model load balancers** ([`router::ModelRouter`]) — the gateway
//!   extracts the model name from the request and routes through a
//!   model-specific [`LoadBalancer`](crate::gateway::lb::LoadBalancer)
//!   whose address pool contains only the instances currently advertising
//!   that model (the Kubernetes pod-label mechanism:
//!   [`Instance::loaded_models`](crate::server::Instance::loaded_models));
//! * **a placement controller** ([`placement::PlacementController`]) —
//!   each instance has a simulated GPU-memory budget (models cost
//!   [`ModelEntry::memory_bytes`](crate::server::ModelEntry::memory_bytes));
//!   a reconcile loop, driven by the cluster's reconcile thread, loads
//!   and unloads models per instance from per-model demand (request rate
//!   from the metrics store plus live queue depth) under that budget —
//!   the snippet's "decision logic based on GPU memory and load".
//!
//! Placement policies:
//!
//! * `static` — the initial placement (balanced rotation of models over
//!   instances, each filled up to its memory budget) never changes by
//!   demand. One exception: min-replica *repairs* run under both
//!   policies — when pod churn kills the last replica of a model, the
//!   reconcile pass re-hosts it (evicting a surplus copy of another
//!   model if memory requires), because losing a model to a pod failure
//!   is not a placement decision. With an unlimited budget static
//!   degenerates to all-models-everywhere.
//! * `dynamic` — the controller moves models toward demand: hot models
//!   gain replicas on instances with free memory (evicting cold surplus
//!   replicas to make room), cold models shrink to a configured minimum.
//!
//! Ordering invariant (checked by the property test): a model is added
//! to an instance's advertised set *before* the instance joins that
//! model's routing pool, and removed from the pool *before* the label is
//! dropped — so the pool is always a subset of the advertisers and a
//! request for model M can never reach an instance that does not have M
//! loaded.
//!
//! **Loads are not instantaneous.** A placement load puts the replica
//! into a `Loading` state for the model's configured `load_delay`
//! (`model_placement.load_delay`, per-model override
//! `server.models[].load_delay`): memory is committed immediately, but
//! the replica stays out of the routing pools and out of placement's
//! warm serving sets until the window ends. The planner charges that
//! window when scoring a move (see [`placement`]) so placement thrash
//! has a realistic price, and the shrink phase never unloads a model's
//! last warm copies while a replacement is still mid-load.
//!
//! The placement controller also feeds **per-model autoscaling**
//! (`autoscaler.per_model`): [`PlacementController::demand_for`] exports
//! the per-model demand signal that
//! [`PerModelScaler`](crate::autoscaler::PerModelScaler) turns into
//! per-model pod targets. Placement moves models across a fixed fleet;
//! per-model scaling changes the fleet itself, spawning pods that boot
//! advertising only the hot model (boot profiles) and preferring
//! scale-down victims whose serving sets are redundant.

//! **Backends.** With the multi-backend engine layer
//! ([`crate::engine`]), placement is additionally *backend-aware*:
//! every instance view carries the backend set its pod's accelerator
//! class advertises, and the planner only ever lands a model on an
//! instance whose set intersects the model's preference list
//! (`server.models[].backends`), preferring the model's first
//! preference and falling back to later ones only when the preferred
//! tier has no capacity. The demand signal is priority-weighted
//! ([`placement::PRIORITY_DEMAND_WEIGHTS`]): a critical backlog scales
//! its model before an equal bulk backlog.

pub mod placement;
pub mod ramp;
pub mod router;

pub use placement::{
    initial_placement, priority_weighted_backlog, InstanceView, Move,
    PlacementController, PlacementCore, PRIORITY_DEMAND_WEIGHTS,
};
pub use ramp::RampTask;
pub use router::ModelRouter;
