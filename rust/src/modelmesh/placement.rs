//! Placement: which models live on which instances.
//!
//! Split like the autoscaler into a pure decision core and a thin driver:
//!
//! * [`PlacementCore`] — given a snapshot of instance states (advertised
//!   models + memory used) and per-model demand, plan `Load`/`Unload`
//!   moves under the per-instance memory budget, with per-(instance,
//!   model) cooldowns and a load/unload hysteresis band. Pure, so it is
//!   unit-tested without threads.
//! * [`PlacementController`] — samples demand (per-model routed-request
//!   rate from the [`MetricStore`] plus live queue depth), feeds the
//!   core, and applies the moves through the [`ModelRouter`] (which owns
//!   the label/pool ordering invariant). Driven by the cluster's
//!   reconcile loop via [`Cluster::set_reconcile_hook`](crate::orchestrator::Cluster::set_reconcile_hook).
//!
//! Demand is `rate + queued`: the routed-request rate answers "how much
//! traffic does this model pull", the live *per-model* batcher backlog
//! answers "is it falling behind right now" — so a saturated model
//! attracts replicas even before the scraped rate catches up, and a
//! shared instance's backlog for *other* models is never misattributed.
//!
//! **Warm-load cost model.** Loads are not free: a planned `Load` puts
//! the replica into `Loading` for the model's configured `load_delay`,
//! during which it consumes memory but serves nothing (and stays out of
//! the router pools). The core therefore charges the delay when scoring
//! a move: a new replica spends `load_delay / horizon` of its guaranteed
//! lifetime (`horizon = max(cooldown, demand_window)`) cold, so the
//! observed per-replica demand is discounted by the warm fraction before
//! being compared to `load_threshold`. Placement thrash now has a
//! realistic price — a move must be worth its load time. Repairs (a
//! model below its replica floor) bypass the charge, exactly like they
//! bypass cooldowns: liveness over economy. Symmetrically, the shrink
//! phase never unloads a model's last warm copies while a replacement is
//! still mid-load.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex};

use crate::config::schema::BACKEND_NAMES;
use crate::config::{ModelPlacementConfig, PlacementPolicy};
use crate::metrics::registry::{labels, Counter, Gauge, Registry};
use crate::metrics::MetricStore;
use crate::modelmesh::router::ModelRouter;
use crate::rpc::codec::Priority;
use crate::server::{split_version, Instance};
use crate::telemetry::flight::{DecisionEvent, LoopTicker, RecorderHandle};
use crate::telemetry::rollback::VERSION_REPLICAS_GAUGE;
use crate::util::clock::Clock;

/// Demand weight per priority class, indexed by [`Priority::index`]: a
/// queued critical request pulls replicas harder than a queued standard
/// one, and a bulk backlog pulls softer — so under equal backlogs the
/// critical model scales first (the PR-4 priority classes reaching the
/// placement signal).
pub const PRIORITY_DEMAND_WEIGHTS: [f64; Priority::COUNT] = [0.5, 1.0, 2.0];

/// Priority-weighted backlog: per-class queued-request counts folded
/// into one demand number using [`PRIORITY_DEMAND_WEIGHTS`].
pub fn priority_weighted_backlog(depths: [usize; Priority::COUNT]) -> f64 {
    depths
        .iter()
        .zip(PRIORITY_DEMAND_WEIGHTS)
        .map(|(&d, w)| d as f64 * w)
        .sum()
}

/// Initial model set for instance number `instance_index`: models are
/// taken in a rotation starting at `instance_index % catalog.len()` and
/// greedily added while the memory budget allows (budget 0 = unlimited,
/// i.e. all-models-everywhere). The rotation balances replicas across
/// models when the budget forces a partition.
pub fn initial_placement(
    catalog: &[(String, u64)],
    budget_bytes: u64,
    instance_index: usize,
) -> Vec<String> {
    let n = catalog.len();
    let mut chosen = Vec::new();
    let mut used = 0u64;
    for k in 0..n {
        let (name, mem) = &catalog[(instance_index + k) % n];
        if budget_bytes == 0 || used + mem <= budget_bytes {
            chosen.push(name.clone());
            used += mem;
        }
    }
    chosen
}

/// Immutable snapshot of one instance for planning.
#[derive(Clone, Debug)]
pub struct InstanceView {
    /// Stable instance id (cooldowns key on it).
    pub id: String,
    /// Advertised (warm) models.
    pub loaded: BTreeSet<String>,
    /// Models mid-load (in their simulated warm-load window): they
    /// occupy memory and count as placed, but serve nothing yet.
    pub loading: BTreeSet<String>,
    /// Memory consumed by the serving set (warm + loading), bytes.
    pub mem_used: u64,
    /// Backend names this instance advertises (its accelerator class's
    /// backend set). An empty set means "unconstrained" — the legacy
    /// single-runtime view; real instances always advertise at least
    /// one backend.
    pub backends: BTreeSet<String>,
}

impl InstanceView {
    /// Is `model` on this instance at all (warm or mid-load)?
    pub fn present(&self, model: &str) -> bool {
        self.loaded.contains(model) || self.loading.contains(model)
    }
}

/// One placement change.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Move {
    /// Load `model` onto `instance`.
    Load { instance: String, model: String },
    /// Unload `model` from `instance`.
    Unload { instance: String, model: String },
}

/// Pure decision logic: demand + memory in, moves out.
pub struct PlacementCore {
    cfg: ModelPlacementConfig,
    /// (model name, memory bytes), demand-independent.
    catalog: Vec<(String, u64)>,
    /// Per-model warm-load time in clock seconds (missing = instant).
    load_costs: BTreeMap<String, f64>,
    /// Per-model backend preference lists (missing model or an empty
    /// map = unconstrained, the legacy single-runtime behavior). A move
    /// only ever lands a model on an instance whose backend set
    /// intersects its list.
    compat: BTreeMap<String, Vec<String>>,
    /// Amortization horizon for the load charge, seconds.
    horizon: f64,
    /// Execution slowdown of a fallback-backend replica relative to
    /// the preferred backend (the engines section's `onnx_slowdown`).
    /// A replica serving on a fallback backend delivers `1/slowdown`
    /// of a preferred replica's throughput, so grow scoring discounts
    /// its value accordingly. `<= 1.0` disables the discount.
    fallback_slowdown: f64,
    /// (instance id, model) -> clock-seconds of the last move.
    cooldowns: BTreeMap<(String, String), f64>,
    /// Retiring model -> successor model (make-before-break). A retiring
    /// model has no replica floor of its own and attracts no growth, but
    /// its *last warm copy* is pinned until the successor is warm
    /// somewhere — a version swap never passes through a state where no
    /// version of the name can serve.
    successors: BTreeMap<String, String>,
}

impl PlacementCore {
    /// Core over a fixed catalog, with instantaneous (free) loads.
    pub fn new(cfg: ModelPlacementConfig, catalog: Vec<(String, u64)>) -> Self {
        Self::with_load_costs(cfg, catalog, BTreeMap::new())
    }

    /// Core that charges each model's warm-load time when scoring moves.
    /// `load_costs` maps model -> load delay in clock seconds.
    pub fn with_load_costs(
        cfg: ModelPlacementConfig,
        catalog: Vec<(String, u64)>,
        load_costs: BTreeMap<String, f64>,
    ) -> Self {
        Self::with_backends(cfg, catalog, load_costs, BTreeMap::new())
    }

    /// [`PlacementCore::with_load_costs`] with per-model backend
    /// preference lists (the [`EngineCatalog`](crate::engine::EngineCatalog)
    /// compat map): moves are planned only onto instances hosting a
    /// compatible backend, preferring earlier-preference backends.
    pub fn with_backends(
        cfg: ModelPlacementConfig,
        catalog: Vec<(String, u64)>,
        load_costs: BTreeMap<String, f64>,
        compat: BTreeMap<String, Vec<String>>,
    ) -> Self {
        let horizon = cfg.load_cost_horizon().as_secs_f64();
        PlacementCore {
            cfg,
            catalog,
            load_costs,
            compat,
            horizon,
            fallback_slowdown: 1.0,
            cooldowns: BTreeMap::new(),
            successors: BTreeMap::new(),
        }
    }

    /// Mark `retiring` as superseded by `successor`: its replica floor
    /// drops to zero and the planner drains it — but never unloads its
    /// last warm copy while no warm copy of `successor` exists (the
    /// make-before-break half of a version swap).
    pub fn set_successor(&mut self, retiring: &str, successor: &str) {
        self.successors
            .insert(retiring.to_string(), successor.to_string());
    }

    /// Undo [`PlacementCore::set_successor`] (a rolled-back canary may be
    /// re-promoted later). Returns whether a mapping existed.
    pub fn clear_successor(&mut self, retiring: &str) -> bool {
        self.successors.remove(retiring).is_some()
    }

    /// Replica floor for `model`: the configured minimum, except retiring
    /// models which owe nothing — `removal_safe` still pins their last
    /// warm copy until the successor serves.
    fn floor_for(&self, model: &str) -> usize {
        if self.successors.contains_key(model) {
            0
        } else {
            self.cfg.min_replicas_per_model
        }
    }

    /// Charge fallback-backend replicas their execution slowdown when
    /// scoring grow moves (see [`PlacementCore::exec_discount`]).
    pub fn with_fallback_slowdown(mut self, slowdown: f64) -> Self {
        self.fallback_slowdown = slowdown;
        self
    }

    /// Can `view` host `model` at all — does its backend set intersect
    /// the model's preference list? Unconstrained when the model has no
    /// compat entry or the view carries no backend info (legacy views).
    fn hostable(&self, view: &InstanceView, model: &str) -> bool {
        match self.compat.get(model) {
            None => true,
            Some(prefs) => {
                view.backends.is_empty() || prefs.iter().any(|b| view.backends.contains(b))
            }
        }
    }

    /// Preference rank of the backend `view` would serve `model` on
    /// (0 = the model's preferred backend; higher = fallback). Used to
    /// order grow candidates so the preferred backend's capacity is
    /// consumed before falling back. Unconstrained views rank 0.
    fn backend_rank(&self, view: &InstanceView, model: &str) -> usize {
        match self.compat.get(model) {
            None => 0,
            Some(prefs) => {
                if view.backends.is_empty() {
                    return 0;
                }
                prefs
                    .iter()
                    .position(|b| view.backends.contains(b))
                    .unwrap_or(usize::MAX)
            }
        }
    }

    /// Per-(instance, backend) execution-cost multiplier for landing
    /// `model` on `view`: a replica serving on a fallback backend runs
    /// `fallback_slowdown`× slower than on the model's preferred
    /// backend, so it absorbs only `1/slowdown` of the demand a
    /// preferred replica would — its marginal value is discounted the
    /// same way the warm-load charge discounts a slow load. 1.0 on the
    /// preferred backend and for unconstrained models/views.
    fn exec_discount(&self, view: &InstanceView, model: &str) -> f64 {
        if self.fallback_slowdown <= 1.0 || self.backend_rank(view, model) == 0 {
            1.0
        } else {
            1.0 / self.fallback_slowdown
        }
    }

    /// Warm fraction of a new replica's guaranteed lifetime: the benefit
    /// multiplier the load charge applies to observed demand. 1.0 for
    /// free loads, approaching 0 as `load_delay` nears the horizon.
    fn load_discount(&self, model: &str) -> f64 {
        let cost = self.load_costs.get(model).copied().unwrap_or(0.0);
        if cost <= 0.0 {
            return 1.0;
        }
        if self.horizon <= 0.0 {
            return 0.0;
        }
        (1.0 - cost / self.horizon).max(0.0)
    }

    fn cooldown_ok(&self, now: f64, instance: &str, model: &str) -> bool {
        match self
            .cooldowns
            .get(&(instance.to_string(), model.to_string()))
        {
            None => true,
            Some(&last) => now - last >= self.cfg.cooldown.as_secs_f64(),
        }
    }

    fn stamp(&mut self, now: f64, instance: &str, model: &str) {
        self.cooldowns
            .insert((instance.to_string(), model.to_string()), now);
    }

    /// Per-model replica counts over a snapshot: `present` (warm +
    /// mid-load — what occupies memory and what growth decisions see)
    /// and `warm` (what actually serves — what the floor protects).
    fn counts(
        &self,
        views: &[InstanceView],
    ) -> (BTreeMap<String, usize>, BTreeMap<String, usize>) {
        let mut present = BTreeMap::new();
        let mut warm = BTreeMap::new();
        for (m, _) in &self.catalog {
            present.insert(m.clone(), views.iter().filter(|v| v.present(m)).count());
            warm.insert(
                m.clone(),
                views.iter().filter(|v| v.loaded.contains(m)).count(),
            );
        }
        (present, warm)
    }

    /// May this copy of `model` on `view` be removed without dropping the
    /// model below its floors? Present count must stay at the floor, and
    /// — when the copy is warm — so must the *warm* count: the last warm
    /// copies are pinned while a replacement is still mid-load.
    ///
    /// Retiring models (see [`PlacementCore::set_successor`]) use the
    /// make-before-break rule instead: a mid-load copy is always
    /// cancelable, and a warm copy may go only while another warm copy of
    /// the model *or of its successor* remains — the swap never strands
    /// the name with nothing warm.
    fn removal_safe(
        &self,
        view: &InstanceView,
        model: &str,
        present: &BTreeMap<String, usize>,
        warm: &BTreeMap<String, usize>,
    ) -> bool {
        if let Some(succ) = self.successors.get(model) {
            if !view.loaded.contains(model) {
                return true;
            }
            return warm[model] > 1
                || warm.get(succ.as_str()).copied().unwrap_or(0) >= 1;
        }
        let min = self.cfg.min_replicas_per_model;
        if present[model] <= min {
            return false;
        }
        // Canceling a mid-load copy never reduces serving capacity.
        view.loading.contains(model) || warm[model] > min
    }

    /// Restore models below their replica floor. Pod churn is not a
    /// placement decision: when the last pod advertising a model dies,
    /// the model must be re-hosted regardless of demand or policy, so
    /// this runs under `static` too (the one exception to "static never
    /// moves models"). If no instance has free memory, a surplus copy of
    /// another model is evicted to make room — never one whose removal
    /// would drop *its* model below the present or warm floor. Repairs
    /// bypass cooldowns and the warm-load charge (liveness over
    /// anti-thrash and economy) but stamp cooldowns, so the demand
    /// phases do not immediately churn a repaired placement.
    fn repair(
        &mut self,
        now: f64,
        views: &mut [InstanceView],
        present: &mut BTreeMap<String, usize>,
        warm: &mut BTreeMap<String, usize>,
        moves: &mut Vec<Move>,
    ) {
        let budget = self.cfg.budget_bytes();
        let catalog = self.catalog.clone();
        for (model, mem) in &catalog {
            while present[model] < self.floor_for(model) {
                // Preferred: a backend-compatible instance with free
                // memory — on the model's preferred backend when one
                // exists, falling back otherwise.
                let direct = views
                    .iter()
                    .filter(|v| !v.present(model) && self.hostable(v, model))
                    .filter(|v| budget == 0 || v.mem_used + mem <= budget)
                    .min_by_key(|v| {
                        (self.backend_rank(v, model), v.mem_used, v.loaded.len() + v.loading.len())
                    })
                    .map(|v| v.id.clone());
                let target = match direct {
                    Some(id) => Some(id),
                    None => {
                        // Evict the most-replicated surplus model from
                        // some compatible instance not hosting `model`,
                        // preferring mid-load copies (canceling a load
                        // costs no serving capacity).
                        let evict = views
                            .iter()
                            .filter(|v| !v.present(model) && self.hostable(v, model))
                            .filter_map(|v| {
                                v.loaded
                                    .iter()
                                    .chain(v.loading.iter())
                                    .filter(|m2| {
                                        self.removal_safe(v, m2, present, warm)
                                    })
                                    .max_by_key(|m2| {
                                        (present[*m2], v.loading.contains(*m2))
                                    })
                                    .map(|m2| (v.id.clone(), m2.clone()))
                            })
                            .max_by_key(|(_, m2)| present[m2]);
                        match evict {
                            None => None,
                            Some((id, victim)) => {
                                let vmem = catalog
                                    .iter()
                                    .find(|(m2, _)| *m2 == victim)
                                    .map(|(_, b)| *b)
                                    .unwrap_or(0);
                                let v = views.iter_mut().find(|v| v.id == id).unwrap();
                                let was_warm = v.loaded.remove(&victim);
                                v.loading.remove(&victim);
                                v.mem_used = v.mem_used.saturating_sub(vmem);
                                *present.get_mut(&victim).unwrap() -= 1;
                                if was_warm {
                                    *warm.get_mut(&victim).unwrap() -= 1;
                                }
                                self.stamp(now, &id, &victim);
                                moves.push(Move::Unload {
                                    instance: id.clone(),
                                    model: victim,
                                });
                                // Only usable if the freed space fits it.
                                let fits = budget == 0
                                    || views
                                        .iter()
                                        .find(|v| v.id == id)
                                        .is_some_and(|v| v.mem_used + mem <= budget);
                                if fits {
                                    Some(id)
                                } else {
                                    None
                                }
                            }
                        }
                    }
                };
                let Some(id) = target else { break }; // nothing can host it
                let v = views.iter_mut().find(|v| v.id == id).unwrap();
                // A planned load begins in `Loading`: it counts as
                // present immediately, warm only once the window ends.
                v.loading.insert(model.clone());
                v.mem_used += mem;
                *present.get_mut(model).unwrap() += 1;
                self.stamp(now, &id, model);
                moves.push(Move::Load { instance: id, model: model.clone() });
            }
        }
    }

    /// Repair-only pass for the `static` policy: restore lost models,
    /// plan no demand-driven moves.
    pub fn plan_repairs(&mut self, now: f64, views: &[InstanceView]) -> Vec<Move> {
        self.plan_repairs_tagged(now, views)
            .into_iter()
            .map(|(m, _)| m)
            .collect()
    }

    /// [`PlacementCore::plan_repairs`] with each move tagged by its
    /// decision kind (always `"repair"` here) for the flight recorder.
    pub fn plan_repairs_tagged(
        &mut self,
        now: f64,
        views: &[InstanceView],
    ) -> Vec<(Move, &'static str)> {
        if views.is_empty() {
            return Vec::new();
        }
        let mut views: Vec<InstanceView> = views.to_vec();
        let (mut present, mut warm) = self.counts(&views);
        let mut moves = Vec::new();
        self.repair(now, &mut views, &mut present, &mut warm, &mut moves);
        moves.into_iter().map(|m| (m, "repair")).collect()
    }

    /// Plan one reconcile pass: repairs first, then at most one unload
    /// and one load per model (gentle convergence); the working copy of
    /// `views` is updated as moves are planned so later decisions see
    /// earlier ones.
    pub fn plan(
        &mut self,
        now: f64,
        views: &[InstanceView],
        demand: &BTreeMap<String, f64>,
    ) -> Vec<Move> {
        self.plan_tagged(now, views, demand)
            .into_iter()
            .map(|(m, _)| m)
            .collect()
    }

    /// [`PlacementCore::plan`] with each move tagged by its decision
    /// kind for the flight recorder: `"repair"` (floor restoration),
    /// `"shrink"` (cold surplus unload), `"swap"` (retiring-version
    /// drain), `"grow"` (hot load).
    pub fn plan_tagged(
        &mut self,
        now: f64,
        views: &[InstanceView],
        demand: &BTreeMap<String, f64>,
    ) -> Vec<(Move, &'static str)> {
        let mut moves: Vec<(Move, &'static str)> = Vec::new();
        if views.is_empty() {
            return moves;
        }
        let mut views: Vec<InstanceView> = views.to_vec();
        let budget = self.cfg.budget_bytes();
        let catalog = self.catalog.clone();
        let (mut present, mut warm) = self.counts(&views);

        // Phase 0 — restore anything below its replica floor.
        let mut repairs = Vec::new();
        self.repair(now, &mut views, &mut present, &mut warm, &mut repairs);
        moves.extend(repairs.into_iter().map(|m| (m, "repair")));

        let d = |m: &str| demand.get(m).copied().unwrap_or(0.0);
        let per_replica = |m: &str, r: usize| d(m) / r.max(1) as f64;

        // Phase 1 — shrink cold models with surplus replicas. Runs first
        // so the freed memory is available to hot loads in the same pass.
        // Retiring models drain regardless of demand (retirement is a
        // version decision, not a load signal) — `removal_safe` keeps
        // the make-before-break pin on their last warm copy.
        for (model, mem) in &catalog {
            let r = present[model];
            if r <= self.floor_for(model) {
                continue;
            }
            let retiring = self.successors.contains_key(model);
            if !retiring && per_replica(model, r) >= self.cfg.unload_threshold {
                continue;
            }
            // Victim: prefer canceling a mid-load copy (it serves
            // nothing either way); among warm copies, the instance under
            // the most memory pressure — and never a warm copy the floor
            // still needs while a replacement is mid-load elsewhere.
            let victim_id = views
                .iter()
                .filter(|v| v.present(model))
                .filter(|v| self.cooldown_ok(now, &v.id, model))
                .filter(|v| self.removal_safe(v, model, &present, &warm))
                .max_by_key(|v| (v.loading.contains(model), v.mem_used))
                .map(|v| v.id.clone());
            if let Some(id) = victim_id {
                let v = views.iter_mut().find(|v| v.id == id).unwrap();
                let was_warm = v.loaded.remove(model);
                v.loading.remove(model);
                v.mem_used = v.mem_used.saturating_sub(*mem);
                *present.get_mut(model).unwrap() -= 1;
                if was_warm {
                    *warm.get_mut(model).unwrap() -= 1;
                }
                self.stamp(now, &id, model);
                moves.push((
                    Move::Unload { instance: id, model: model.clone() },
                    if retiring { "swap" } else { "shrink" },
                ));
            }
        }

        // Phase 2 — grow hot models, hottest first. The warm-load
        // charge: a new replica spends `load_delay` of its guaranteed
        // lifetime cold, so the observed per-replica demand is
        // discounted by the warm fraction before the threshold test —
        // a move must be worth its load time.
        let mut hot: Vec<(String, u64, f64)> = catalog
            .iter()
            .filter(|(m, _)| !self.successors.contains_key(m))
            .filter_map(|(m, mem)| {
                let load = per_replica(m, present[m]) * self.load_discount(m);
                (load > self.cfg.load_threshold).then(|| (m.clone(), *mem, load))
            })
            .collect();
        hot.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap_or(std::cmp::Ordering::Equal));
        for (model, mem, load) in hot {
            // Candidate: backend-compatible, not already hosting (warm
            // or mid-load), off cooldown, with free memory, and worth
            // its execution cost — a fallback-backend replica absorbs
            // only `1/fallback_slowdown` of the demand, so its
            // discounted benefit must still clear the bar. Preference
            // order: instances serving the model on its *preferred*
            // backend first, then fallback backends (only used when the
            // preferred tier has no capacity), emptiest instance within
            // a tier.
            let candidate_id = views
                .iter()
                .filter(|v| !v.present(&model) && self.hostable(v, &model))
                .filter(|v| self.cooldown_ok(now, &v.id, &model))
                .filter(|v| budget == 0 || v.mem_used + mem <= budget)
                .filter(|v| load * self.exec_discount(v, &model) > self.cfg.load_threshold)
                .min_by_key(|v| {
                    (self.backend_rank(v, &model), v.mem_used, v.loaded.len() + v.loading.len())
                })
                .map(|v| v.id.clone());
            if let Some(id) = candidate_id {
                let v = views.iter_mut().find(|v| v.id == id).unwrap();
                v.loading.insert(model.clone());
                v.mem_used += mem;
                *present.get_mut(&model).unwrap() += 1;
                self.stamp(now, &id, &model);
                moves.push((Move::Load { instance: id, model }, "grow"));
            }
        }
        moves
    }
}

struct ModelHandles {
    loads: Counter,
    unloads: Counter,
    replicas: Gauge,
    /// Replicas currently inside their warm-load window.
    loading: Gauge,
    /// Warm replicas served per backend (`model_backend_replicas`),
    /// keyed by backend name.
    backend_replicas: BTreeMap<&'static str, Gauge>,
    /// For versioned catalog entries (`base@vN`): the same replica count
    /// re-exported as `model_version_replicas{model="base", version="vN"}`
    /// — the per-version dashboard view of a rollout.
    version_replicas: Option<Gauge>,
}

/// The running placement controller.
pub struct PlacementController {
    cfg: ModelPlacementConfig,
    catalog: Vec<(String, u64)>,
    router: Arc<ModelRouter>,
    store: MetricStore,
    clock: Clock,
    core: Mutex<PlacementCore>,
    per_model: BTreeMap<String, ModelHandles>,
    m_moves: Counter,
    /// Federation site this controller is local to (`None` =
    /// single-cluster). Scopes the demand signal to the site's
    /// `routed_requests_total{model=...,site=...}` series.
    site: Option<String>,
    recorder: RecorderHandle,
    ticker: LoopTicker,
}

impl PlacementController {
    /// Controller over `catalog` (model name + memory bytes), applying
    /// moves through `router`. `load_costs` maps model -> warm-load
    /// delay in clock seconds (the deployment resolves per-model
    /// overrides against `model_placement.load_delay`); missing entries
    /// load free. `compat` is the engine catalog's per-model backend
    /// preference map — the planner never lands a model on an instance
    /// without a compatible backend (empty = unconstrained).
    /// `fallback_slowdown` is the engines section's `onnx_slowdown`:
    /// grow scoring discounts a fallback-backend replica's value by it.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        cfg: ModelPlacementConfig,
        catalog: Vec<(String, u64)>,
        load_costs: BTreeMap<String, f64>,
        compat: BTreeMap<String, Vec<String>>,
        fallback_slowdown: f64,
        router: Arc<ModelRouter>,
        store: MetricStore,
        clock: Clock,
        registry: &Registry,
    ) -> Arc<Self> {
        Self::new_inner(
            cfg, catalog, load_costs, compat, fallback_slowdown, router, store, clock, registry,
            None,
        )
    }

    /// [`PlacementController::new`] as one federation site's local loop:
    /// every placement series gains a `site` label and the demand signal
    /// reads the site-labeled routed counters, so each site places from
    /// its own traffic while the global rebalancer aggregates across
    /// sites.
    #[allow(clippy::too_many_arguments)]
    pub fn new_for_site(
        cfg: ModelPlacementConfig,
        catalog: Vec<(String, u64)>,
        load_costs: BTreeMap<String, f64>,
        compat: BTreeMap<String, Vec<String>>,
        fallback_slowdown: f64,
        router: Arc<ModelRouter>,
        store: MetricStore,
        clock: Clock,
        registry: &Registry,
        site: &str,
    ) -> Arc<Self> {
        Self::new_inner(
            cfg, catalog, load_costs, compat, fallback_slowdown, router, store, clock, registry,
            Some(site),
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn new_inner(
        cfg: ModelPlacementConfig,
        catalog: Vec<(String, u64)>,
        load_costs: BTreeMap<String, f64>,
        compat: BTreeMap<String, Vec<String>>,
        fallback_slowdown: f64,
        router: Arc<ModelRouter>,
        store: MetricStore,
        clock: Clock,
        registry: &Registry,
        site: Option<&str>,
    ) -> Arc<Self> {
        // Label helper: appends the site pair in federated mode, so the
        // same series names serve both modes (single-site stays
        // label-identical to the pre-federation exposition).
        let with_site = |pairs: &[(&str, &str)]| match site {
            None => labels(pairs),
            Some(site) => {
                let mut all: Vec<(&str, &str)> = pairs.to_vec();
                all.push(("site", site));
                labels(&all)
            }
        };
        let per_model = catalog
            .iter()
            .map(|(m, _)| {
                let l = with_site(&[("model", m)]);
                let backend_replicas = BACKEND_NAMES
                    .iter()
                    .map(|b| {
                        (
                            *b,
                            registry.gauge(
                                "model_backend_replicas",
                                &with_site(&[("model", m), ("backend", b)]),
                            ),
                        )
                    })
                    .collect();
                let version_replicas = match split_version(m) {
                    (base, Some(v)) => Some(registry.gauge(
                        VERSION_REPLICAS_GAUGE,
                        &with_site(&[("model", base), ("version", &format!("v{v}"))]),
                    )),
                    _ => None,
                };
                (
                    m.clone(),
                    ModelHandles {
                        loads: registry.counter("model_load_events_total", &l),
                        unloads: registry.counter("model_unload_events_total", &l),
                        replicas: registry.gauge("model_replicas", &l),
                        loading: registry.gauge("model_replicas_loading", &l),
                        backend_replicas,
                        version_replicas,
                    },
                )
            })
            .collect();
        Arc::new(PlacementController {
            core: Mutex::new(
                PlacementCore::with_backends(cfg.clone(), catalog.clone(), load_costs, compat)
                    .with_fallback_slowdown(fallback_slowdown),
            ),
            cfg,
            catalog,
            router,
            store,
            ticker: LoopTicker::new(registry, clock.clone(), "placement"),
            clock,
            per_model,
            m_moves: registry.counter("placement_moves_total", &with_site(&[])),
            site: site.map(String::from),
            recorder: RecorderHandle::default(),
        })
    }

    /// The flight-recorder slot placement decisions land in (installed
    /// by the deployment once the recorder exists).
    pub fn recorder(&self) -> &RecorderHandle {
        &self.recorder
    }

    /// Demand signal for one model: scraped routed-request rate over the
    /// demand window plus the live *per-model* batcher backlog across
    /// its pool (the affinity batcher's per-(instance, model) queues
    /// make this exact — an instance's backlog for other models is not
    /// misattributed). The backlog term is **priority-weighted**
    /// ([`PRIORITY_DEMAND_WEIGHTS`]): a critical backlog pulls replicas
    /// harder than an equal bulk backlog, so the models critical
    /// traffic depends on scale first. This is the controller's export
    /// API — the per-model autoscaler consumes the same signal the
    /// placement planner does, so pod scaling and model placement pull
    /// in the same direction.
    /// Version-blindness guard: asked about a bare name, the signal
    /// aggregates over every catalog version of it (`base@vN`), so the
    /// pod autoscaler sees the canary's backlog too — a rollout's demand
    /// does not vanish from the scaler when it splits across versions.
    pub fn demand_for(&self, model: &str, now: f64) -> f64 {
        let names: Vec<&str> = self
            .catalog
            .iter()
            .map(|(n, _)| n.as_str())
            .filter(|n| *n == model || split_version(n).0 == model)
            .collect();
        if names.is_empty() {
            return self.demand_one(model, now);
        }
        names.iter().map(|n| self.demand_one(n, now)).sum()
    }

    fn demand_one(&self, model: &str, now: f64) -> f64 {
        // Labels render alphabetically, so `model` precedes `site`.
        let series = match &self.site {
            None => format!("routed_requests_total{{model=\"{model}\"}}"),
            Some(site) => {
                format!("routed_requests_total{{model=\"{model}\",site=\"{site}\"}}")
            }
        };
        let rate = self
            .store
            .rate_over(&series, now, self.cfg.demand_window)
            .unwrap_or(0.0);
        let queued: f64 = self
            .router
            .endpoints_for(model)
            .iter()
            .map(|i| priority_weighted_backlog(i.queue_depth_prio_for(model)))
            .sum();
        rate + queued
    }

    /// Demand for every catalog model at `now` (see
    /// [`PlacementController::demand_for`]).
    pub fn demand_snapshot(&self, now: f64) -> BTreeMap<String, f64> {
        self.catalog
            .iter()
            .map(|(m, _)| (m.clone(), self.demand_for(m, now)))
            .collect()
    }

    /// One reconcile pass: refresh the routing pools from the instance
    /// labels, then plan and apply placement moves — min-replica repairs
    /// under both policies (a model whose last pod died must be
    /// re-hosted), demand-driven moves under `dynamic` only. Called from
    /// the cluster reconcile loop; each pass lands in the placement
    /// loop-health series.
    pub fn reconcile(&self, endpoints: &[Arc<Instance>]) {
        self.ticker.tick(|| self.reconcile_inner(endpoints));
    }

    fn reconcile_inner(&self, endpoints: &[Arc<Instance>]) {
        self.router.sync(endpoints);
        let now = self.clock.now_secs();
        let views: Vec<InstanceView> = endpoints
            .iter()
            .map(|i| {
                // One consistent snapshot per instance: taking warm,
                // loading and memory separately could catch a model
                // mid-transition in neither set and trigger a spurious
                // repair.
                let (warm, loading, mem_used) = i.placement_snapshot();
                InstanceView {
                    id: i.id.clone(),
                    loaded: warm.into_iter().collect(),
                    loading: loading.into_iter().collect(),
                    mem_used,
                    backends: i.backend_names().into_iter().collect(),
                }
            })
            .collect();
        let (moves, demand) = if self.cfg.policy == PlacementPolicy::Dynamic {
            let demand = self.demand_snapshot(now);
            let moves = self.core.lock().unwrap().plan_tagged(now, &views, &demand);
            (moves, Some(demand))
        } else {
            (self.core.lock().unwrap().plan_repairs_tagged(now, &views), None)
        };
        self.apply(endpoints, moves, demand.as_ref());
        // One consistent (warm model -> backend) snapshot per instance:
        // the gauge refresh below must not re-take each instance's
        // serving-set lock per (model, backend) pair, nor pair two
        // non-atomic reads that could tear across a warm transition.
        let served: Vec<_> = endpoints.iter().map(|i| i.warm_backends()).collect();
        for (m, h) in &self.per_model {
            let warm = self.router.replicas(m) as f64;
            h.replicas.set(warm);
            if let Some(g) = &h.version_replicas {
                g.set(warm);
            }
            h.loading
                .set(endpoints.iter().filter(|i| i.is_loading(m)).count() as f64);
            // Warm replicas per serving backend (the heterogeneity
            // dashboard view: where does each model actually run).
            for (backend, gauge) in &h.backend_replicas {
                let n = served
                    .iter()
                    .filter(|s| s.get(m).map(String::as_str) == Some(*backend))
                    .count();
                gauge.set(n as f64);
            }
        }
    }

    /// Begin a make-before-break swap: `retiring` drains (floor zero, no
    /// growth) but its last warm copy stays pinned until `successor` is
    /// warm somewhere. Called on canary promotion (old incumbent retires)
    /// and on auto-rollback (the canary retires).
    pub fn set_successor(&self, retiring: &str, successor: &str) {
        log::info!("modelmesh: retiring '{retiring}' in favor of '{successor}'");
        self.core.lock().unwrap().set_successor(retiring, successor);
    }

    /// Undo [`PlacementController::set_successor`]; returns whether a
    /// mapping existed.
    pub fn clear_successor(&self, retiring: &str) -> bool {
        self.core.lock().unwrap().clear_successor(retiring)
    }

    fn apply(
        &self,
        endpoints: &[Arc<Instance>],
        moves: Vec<(Move, &'static str)>,
        demand: Option<&BTreeMap<String, f64>>,
    ) {
        for (mv, kind) in moves {
            match mv {
                Move::Load { instance, model } => {
                    if let Some(inst) = endpoints.iter().find(|i| i.id == instance) {
                        if self.router.load(inst, &model) {
                            log::info!("modelmesh: loaded '{model}' on {instance}");
                            self.per_model[&model].loads.inc();
                            self.m_moves.inc();
                            self.record_move(kind, &model, &instance, "load", demand);
                        }
                    }
                }
                Move::Unload { instance, model } => {
                    if let Some(inst) = endpoints.iter().find(|i| i.id == instance) {
                        if self.router.unload(inst, &model) {
                            log::info!("modelmesh: unloaded '{model}' from {instance}");
                            self.per_model[&model].unloads.inc();
                            self.m_moves.inc();
                            self.record_move(kind, &model, &instance, "unload", demand);
                        }
                    }
                }
            }
        }
    }

    /// One applied placement move into the flight recorder.
    fn record_move(
        &self,
        kind: &'static str,
        model: &str,
        instance: &str,
        verb: &str,
        demand: Option<&BTreeMap<String, f64>>,
    ) {
        let mut ev = DecisionEvent::new("placement", kind)
            .model(model)
            .action(format!("{verb} '{model}' on {instance}"));
        if let Some(d) = demand.and_then(|d| d.get(model)) {
            ev = ev.input("demand", *d);
        }
        if let Some(site) = &self.site {
            ev = ev.site(site);
        }
        self.recorder.record(ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn cfg() -> ModelPlacementConfig {
        ModelPlacementConfig {
            policy: PlacementPolicy::Dynamic,
            memory_budget_mb: 1.0, // 1_000_000 bytes
            load_threshold: 100.0,
            unload_threshold: 20.0,
            cooldown: Duration::from_secs(5),
            demand_window: Duration::from_secs(10),
            min_replicas_per_model: 1,
            load_delay: Duration::ZERO,
        }
    }

    /// Two models of 600 KB each: an instance fits exactly one.
    fn catalog() -> Vec<(String, u64)> {
        vec![("hot".to_string(), 600_000), ("cold".to_string(), 600_000)]
    }

    fn view(id: &str, models: &[&str]) -> InstanceView {
        view_loading(id, models, &[])
    }

    /// View with explicit warm and mid-load sets (600 KB per model).
    fn view_loading(id: &str, warm: &[&str], loading: &[&str]) -> InstanceView {
        InstanceView {
            id: id.to_string(),
            loaded: warm.iter().map(|m| m.to_string()).collect(),
            loading: loading.iter().map(|m| m.to_string()).collect(),
            mem_used: (warm.len() + loading.len()) as u64 * 600_000,
            backends: BTreeSet::new(),
        }
    }

    /// View with an explicit backend set.
    fn view_backends(id: &str, warm: &[&str], backends: &[&str]) -> InstanceView {
        InstanceView {
            backends: backends.iter().map(|b| b.to_string()).collect(),
            ..view(id, warm)
        }
    }

    fn demand(hot: f64, cold: f64) -> BTreeMap<String, f64> {
        [("hot".to_string(), hot), ("cold".to_string(), cold)]
            .into_iter()
            .collect()
    }

    #[test]
    fn initial_placement_rotates_under_budget() {
        let cat = catalog();
        // budget fits one model: rotation alternates
        assert_eq!(initial_placement(&cat, 700_000, 0), vec!["hot"]);
        assert_eq!(initial_placement(&cat, 700_000, 1), vec!["cold"]);
        assert_eq!(initial_placement(&cat, 700_000, 2), vec!["hot"]);
        // unlimited budget: everything everywhere
        assert_eq!(initial_placement(&cat, 0, 0), vec!["hot", "cold"]);
        // budget fits both
        assert_eq!(initial_placement(&cat, 2_000_000, 1), vec!["cold", "hot"]);
    }

    #[test]
    fn hot_model_claims_cold_surplus_replica() {
        let mut core = PlacementCore::new(cfg(), catalog());
        // 2 hot + 2 cold replicas; hot overloaded, cold idle.
        let views = vec![
            view("i0", &["hot"]),
            view("i1", &["hot"]),
            view("i2", &["cold"]),
            view("i3", &["cold"]),
        ];
        let moves = core.plan(0.0, &views, &demand(500.0, 5.0));
        // cold shrinks to 1 replica, hot grows onto the freed instance
        assert!(
            moves.iter().any(|m| matches!(m, Move::Unload { model, .. } if model == "cold")),
            "{moves:?}"
        );
        assert!(
            moves.iter().any(|m| matches!(m, Move::Load { model, .. } if model == "hot")),
            "{moves:?}"
        );
        // and the load landed on the instance the unload freed
        let unloaded = moves.iter().find_map(|m| match m {
            Move::Unload { instance, .. } => Some(instance.clone()),
            _ => None,
        });
        let loaded = moves.iter().find_map(|m| match m {
            Move::Load { instance, .. } => Some(instance.clone()),
            _ => None,
        });
        assert_eq!(unloaded, loaded, "{moves:?}");
    }

    #[test]
    fn min_replicas_never_violated() {
        let mut core = PlacementCore::new(cfg(), catalog());
        // cold has exactly one replica: zero demand must not unload it.
        let views = vec![view("i0", &["hot"]), view("i1", &["cold"])];
        let moves = core.plan(0.0, &views, &demand(500.0, 0.0));
        assert!(
            !moves.iter().any(|m| matches!(m, Move::Unload { model, .. } if model == "cold")),
            "{moves:?}"
        );
    }

    #[test]
    fn memory_budget_blocks_overpacking() {
        let mut core = PlacementCore::new(cfg(), catalog());
        // Every instance is full and cold is not unloadable (demand in
        // the hysteresis band): hot cannot be placed anywhere.
        let views = vec![view("i0", &["hot"]), view("i1", &["cold"])];
        let moves = core.plan(0.0, &views, &demand(500.0, 50.0));
        assert!(moves.is_empty(), "{moves:?}");
    }

    #[test]
    fn hysteresis_band_holds() {
        let mut core = PlacementCore::new(cfg(), catalog());
        // per-replica loads inside (unload, load) thresholds: no churn.
        let views = vec![
            view("i0", &["hot"]),
            view("i1", &["hot"]),
            view("i2", &["cold"]),
        ];
        let moves = core.plan(0.0, &views, &demand(120.0, 60.0));
        assert!(moves.is_empty(), "{moves:?}");
    }

    #[test]
    fn cooldown_spaces_moves_per_instance_model() {
        // Unlimited memory, one possible target: the cooldown is the only
        // thing spacing repeated loads of hot onto i1.
        let mut c = cfg();
        c.memory_budget_mb = 0.0;
        let mut core = PlacementCore::new(c, catalog());
        let views = vec![view("i0", &["hot"]), view("i1", &["cold"])];
        let moves = core.plan(0.0, &views, &demand(500.0, 50.0));
        assert_eq!(
            moves,
            vec![Move::Load { instance: "i1".to_string(), model: "hot".to_string() }]
        );
        // Same (stale) snapshot inside the cooldown window: no repeat.
        let again = core.plan(1.0, &views, &demand(500.0, 50.0));
        assert!(again.is_empty(), "{again:?}");
        // After the cooldown expires the same state plans again.
        let later = core.plan(10.0, &views, &demand(500.0, 50.0));
        assert_eq!(later.len(), 1);
    }

    #[test]
    fn unlimited_budget_spreads_hot_model() {
        let mut c = cfg();
        c.memory_budget_mb = 0.0;
        let mut core = PlacementCore::new(c, catalog());
        let views = vec![view("i0", &["hot", "cold"]), view("i1", &["cold"])];
        let moves = core.plan(0.0, &views, &demand(500.0, 50.0));
        assert_eq!(
            moves,
            vec![Move::Load { instance: "i1".to_string(), model: "hot".to_string() }]
        );
    }

    #[test]
    fn empty_cluster_plans_nothing() {
        let mut core = PlacementCore::new(cfg(), catalog());
        assert!(core.plan(0.0, &[], &demand(500.0, 5.0)).is_empty());
        assert!(core.plan_repairs(0.0, &[]).is_empty());
    }

    #[test]
    fn lost_model_restored_even_when_cold() {
        // The cold model's last pod died: it has zero replicas and demand
        // far below load_threshold. Repair must still re-host it, evicting
        // a surplus hot copy because every instance is full.
        let mut core = PlacementCore::new(cfg(), catalog());
        let views = vec![view("i0", &["hot"]), view("i1", &["hot"])];
        let moves = core.plan(0.0, &views, &demand(30.0, 5.0));
        assert!(
            moves.iter().any(|m| matches!(m, Move::Load { model, .. } if model == "cold")),
            "lost cold model not restored: {moves:?}"
        );
        assert!(
            moves.iter().any(|m| matches!(m, Move::Unload { model, .. } if model == "hot")),
            "no room was made for the repair: {moves:?}"
        );
    }

    #[test]
    fn plan_repairs_restores_under_static_policy() {
        let mut c = cfg();
        c.policy = PlacementPolicy::Static;
        let mut core = PlacementCore::new(c, catalog());
        // free instance available: direct load, no eviction needed
        let views = vec![
            view("i0", &["hot"]),
            InstanceView {
                id: "i1".into(),
                loaded: BTreeSet::new(),
                loading: BTreeSet::new(),
                mem_used: 0,
                backends: BTreeSet::new(),
            },
        ];
        let moves = core.plan_repairs(0.0, &views);
        assert_eq!(
            moves,
            vec![Move::Load { instance: "i1".to_string(), model: "cold".to_string() }]
        );
        // healthy fleet: repairs plan nothing (static stays static)
        let healthy = vec![view("i0", &["hot"]), view("i1", &["cold"])];
        assert!(core.plan_repairs(1.0, &healthy).is_empty());
    }

    #[test]
    fn shrink_prefers_canceling_midload_copy() {
        let mut core = PlacementCore::new(cfg(), catalog());
        // cold: one warm copy (i0) and one mid-load copy (i1), both idle.
        let views = vec![
            view_loading("i0", &["cold"], &[]),
            view_loading("i1", &[], &["cold"]),
            view("i2", &["hot"]),
        ];
        let moves = core.plan(0.0, &views, &demand(50.0, 0.0));
        assert_eq!(
            moves,
            vec![Move::Unload { instance: "i1".to_string(), model: "cold".to_string() }],
            "should cancel the load, not drop the serving copy"
        );
        // Same (stale) snapshot: i1 is now on cooldown, and the only
        // other copy is the LAST WARM one — the floor pins it even
        // though the present count (2) is above the floor.
        let again = core.plan(1.0, &views, &demand(50.0, 0.0));
        assert!(
            !again
                .iter()
                .any(|m| matches!(m, Move::Unload { model, .. } if model == "cold")),
            "unloaded the last warm copy while its replacement was mid-load: {again:?}"
        );
    }

    #[test]
    fn load_charge_suppresses_marginal_moves() {
        // horizon = max(cooldown 5, demand_window 10) = 10 s; a 5 s load
        // delay halves the expected benefit of a new replica.
        let mut c = cfg();
        c.memory_budget_mb = 0.0; // memory out of the way
        let costs: BTreeMap<String, f64> = [("hot".to_string(), 5.0)].into_iter().collect();
        let mut core = PlacementCore::with_load_costs(c.clone(), catalog(), costs);
        let views = vec![view("i0", &["hot"]), view("i1", &["cold"])];
        // 180 per-replica demand: free loads would move (180 > 100), but
        // the discounted benefit 180 * 0.5 = 90 does not clear the bar.
        let moves = core.plan(0.0, &views, &demand(180.0, 50.0));
        assert!(moves.is_empty(), "marginal move not suppressed: {moves:?}");
        // 250 per-replica demand amortizes the load (125 > 100).
        let moves = core.plan(20.0, &views, &demand(250.0, 50.0));
        assert_eq!(
            moves,
            vec![Move::Load { instance: "i1".to_string(), model: "hot".to_string() }]
        );
        // Sanity: with free loads the marginal demand does move.
        let mut free = PlacementCore::new(c, catalog());
        let moves = free.plan(0.0, &views, &demand(180.0, 50.0));
        assert_eq!(moves.len(), 1, "{moves:?}");
    }

    #[test]
    fn loading_copy_counts_as_present() {
        let mut core = PlacementCore::new(cfg(), catalog());
        // hot already has a replacement mid-load on i1: per-replica
        // demand is halved and no third copy fits the budget, so the
        // planner must not re-plan the same load every pass.
        let views = vec![
            view_loading("i0", &["hot"], &[]),
            view_loading("i1", &[], &["hot"]),
            view("i2", &["cold"]),
        ];
        let moves = core.plan(0.0, &views, &demand(500.0, 50.0));
        assert!(
            !moves
                .iter()
                .any(|m| matches!(m, Move::Load { model, .. } if model == "hot")),
            "planned a duplicate load while one was in flight: {moves:?}"
        );
    }

    /// Compat map: hot runs anywhere (pjrt preferred), cold is
    /// onnx-sim-only (CPU-pinned).
    fn compat() -> BTreeMap<String, Vec<String>> {
        [
            ("hot".to_string(), vec!["pjrt".to_string(), "onnx-sim".to_string()]),
            ("cold".to_string(), vec!["onnx-sim".to_string()]),
        ]
        .into_iter()
        .collect()
    }

    fn backend_core(c: ModelPlacementConfig) -> PlacementCore {
        PlacementCore::with_backends(c, catalog(), BTreeMap::new(), compat())
    }

    #[test]
    fn grow_never_lands_on_incompatible_backend() {
        let mut c = cfg();
        c.memory_budget_mb = 0.0;
        let mut core = backend_core(c);
        // cold is overloaded; the only instance without it is GPU-only
        // (pjrt) — incompatible, so no load is planned at all.
        let views = vec![
            view_backends("cpu0", &["cold"], &["onnx-sim"]),
            view_backends("gpu0", &["hot"], &["pjrt"]),
        ];
        let moves = core.plan(0.0, &views, &demand(50.0, 500.0));
        assert!(
            !moves.iter().any(|m| matches!(m, Move::Load { model, .. } if model == "cold")),
            "planned a cold load onto a pjrt-only instance: {moves:?}"
        );
    }

    #[test]
    fn repair_skips_incompatible_hosts_and_gives_up() {
        // cold lost its last replica; the only candidates are GPU-only:
        // the repair pass must give up, not place an unservable copy.
        let mut c = cfg();
        c.memory_budget_mb = 0.0;
        let mut core = backend_core(c);
        let views = vec![
            view_backends("gpu0", &["hot"], &["pjrt"]),
            view_backends("gpu1", &["hot"], &["pjrt"]),
        ];
        let moves = core.plan(0.0, &views, &demand(50.0, 5.0));
        assert!(
            !moves.iter().any(|m| matches!(m, Move::Load { model, .. } if model == "cold")),
            "repair placed cold on an incompatible instance: {moves:?}"
        );
        // With a CPU pod in the fleet, the repair lands there.
        let views = vec![
            view_backends("gpu0", &["hot"], &["pjrt"]),
            view_backends("cpu0", &[], &["onnx-sim"]),
        ];
        let moves = core.plan(1.0, &views, &demand(50.0, 5.0));
        assert_eq!(
            moves,
            vec![Move::Load { instance: "cpu0".to_string(), model: "cold".to_string() }]
        );
    }

    #[test]
    fn grow_prefers_preferred_backend_then_falls_back() {
        let mut c = cfg();
        c.memory_budget_mb = 0.0;
        let mut core = backend_core(c.clone());
        // hot is overloaded; both a GPU (preferred backend, fuller) and
        // a CPU (fallback, already hosting cold) could take a replica:
        // the preferred tier wins despite the memory tiebreak.
        let views = vec![
            view_backends("src", &["hot"], &["pjrt"]),
            InstanceView {
                mem_used: 600_000,
                ..view_backends("gpu0", &[], &["pjrt"])
            },
            view_backends("cpu0", &["cold"], &["onnx-sim"]),
        ];
        let moves = core.plan(0.0, &views, &demand(500.0, 50.0));
        assert_eq!(
            moves,
            vec![Move::Load { instance: "gpu0".to_string(), model: "hot".to_string() }]
        );
        // With no pjrt capacity left, the fallback tier is used.
        let mut core = backend_core(c);
        let views = vec![
            view_backends("src", &["hot"], &["pjrt"]),
            view_backends("cpu0", &["cold"], &["onnx-sim"]),
        ];
        let moves = core.plan(0.0, &views, &demand(500.0, 50.0));
        assert_eq!(
            moves,
            vec![Move::Load { instance: "cpu0".to_string(), model: "hot".to_string() }]
        );
    }

    #[test]
    fn gpu_candidate_outranks_equal_fallback_candidate() {
        // hot is overloaded; two otherwise-equal empty candidates — one
        // on the preferred backend (pjrt), one fallback-only (onnx-sim).
        // The grow move must land on the GPU: a fallback replica is
        // worth 1/slowdown as much per unit of demand.
        let mut c = cfg();
        c.memory_budget_mb = 0.0;
        let mut core = backend_core(c).with_fallback_slowdown(4.0);
        let views = vec![
            view_backends("src", &["hot"], &["pjrt"]),
            InstanceView { mem_used: 600_000, ..view_backends("gpu0", &[], &["pjrt"]) },
            view_backends("cpu0", &["cold"], &["onnx-sim"]),
        ];
        let moves = core.plan(0.0, &views, &demand(500.0, 50.0));
        assert_eq!(
            moves,
            vec![Move::Load { instance: "gpu0".to_string(), model: "hot".to_string() }]
        );
    }

    #[test]
    fn fallback_candidate_needs_slowdown_times_more_load() {
        // Only a fallback (onnx-sim) candidate is available and the
        // replica would serve 4x slower there: demand that clears the
        // bare threshold (150 > 100) is not worth a replica delivering
        // a quarter of the throughput (150 * 1/4 = 37.5), but demand
        // above slowdown * threshold is (500 * 1/4 = 125 > 100).
        let mut c = cfg();
        c.memory_budget_mb = 0.0;
        let mut core = backend_core(c.clone()).with_fallback_slowdown(4.0);
        let views = vec![
            view_backends("src", &["hot"], &["pjrt"]),
            view_backends("cpu0", &["cold"], &["onnx-sim"]),
        ];
        let moves = core.plan(0.0, &views, &demand(150.0, 50.0));
        assert!(moves.is_empty(), "underwater fallback replica planned: {moves:?}");
        let moves = core.plan(1.0, &views, &demand(500.0, 50.0));
        assert_eq!(
            moves,
            vec![Move::Load { instance: "cpu0".to_string(), model: "hot".to_string() }]
        );
        // Sanity: without the discount the marginal demand does move.
        let mut flat = backend_core(c);
        let moves = flat.plan(0.0, &views, &demand(150.0, 50.0));
        assert_eq!(moves.len(), 1, "{moves:?}");
    }

    #[test]
    fn demand_for_scales_critical_backlog_before_equal_bulk() {
        use crate::config::{ExecutionMode, LbPolicy, ModelConfig, ServiceModelConfig};
        use crate::runtime::Tensor;
        use crate::server::{InstanceOptions, ModelRepository};

        // One stuck instance serving two models; equal-sized backlogs —
        // bulk on the cnn, critical on particlenet — must yield a
        // strictly higher demand signal for the critical model.
        let models = ["icecube_cnn", "particlenet"];
        let repo = Arc::new(
            ModelRepository::load_metadata(
                std::path::Path::new("artifacts"),
                &models.map(String::from),
            )
            .unwrap(),
        );
        let model_cfgs: Vec<ModelConfig> = models
            .iter()
            .map(|m| ModelConfig {
                name: m.to_string(),
                max_queue_delay: Duration::from_millis(1),
                preferred_batch: 8,
                // Huge base service: the executor sticks on the first
                // request, so later submits stay queued.
                service_model: ServiceModelConfig {
                    base: Duration::from_secs(10),
                    per_row: Duration::from_micros(1),
                },
                ..ModelConfig::default()
            })
            .collect();
        // 50x dilation keeps the stuck 10 s (clock) service — and the
        // drain on stop() — at a few hundred real milliseconds.
        let clock = Clock::scaled(50.0);
        let inst = crate::server::Instance::start_with_opts(
            "dw0",
            Arc::clone(&repo),
            &model_cfgs,
            clock.clone(),
            Registry::new(),
            InstanceOptions { exec_mode: ExecutionMode::Simulated, ..Default::default() },
        );
        inst.mark_ready();
        let cnn = || Tensor::zeros(vec![1, 16, 16, 3]);
        let pn = || Tensor::zeros(vec![1, 64, 7]);
        // Occupy the executor, then queue equal backlogs per model.
        let mut rxs = Vec::new();
        rxs.push(inst.submit("icecube_cnn", cnn(), 0).unwrap());
        std::thread::sleep(Duration::from_millis(100));
        for i in 0..3 {
            rxs.push(inst.submit_prio("icecube_cnn", cnn(), Priority::Bulk, i).unwrap());
            rxs.push(inst.submit_prio("particlenet", pn(), Priority::Critical, i).unwrap());
        }
        let registry = Registry::new();
        let router = Arc::new(ModelRouter::new(
            &models.map(String::from),
            LbPolicy::RoundRobin,
            0,
            &registry,
            7,
        ));
        router.sync(&[Arc::clone(&inst)]);
        let catalog: Vec<(String, u64)> =
            models.iter().map(|m| (m.to_string(), 1)).collect();
        let controller = PlacementController::new(
            cfg(),
            catalog,
            BTreeMap::new(),
            BTreeMap::new(),
            1.0,
            Arc::clone(&router),
            MetricStore::new(Duration::from_secs(60)),
            clock.clone(),
            &registry,
        );
        let now = clock.now_secs();
        let bulk_demand = controller.demand_for("icecube_cnn", now);
        let critical_demand = controller.demand_for("particlenet", now);
        assert!(
            critical_demand > bulk_demand,
            "equal backlogs, but critical ({critical_demand}) did not outweigh \
             bulk ({bulk_demand})"
        );
        inst.stop();
    }

    #[test]
    fn priority_weighted_backlog_orders_classes() {
        // Equal backlogs: critical outweighs standard outweighs bulk.
        let bulk = priority_weighted_backlog([10, 0, 0]);
        let standard = priority_weighted_backlog([0, 10, 0]);
        let critical = priority_weighted_backlog([0, 0, 10]);
        assert!(critical > standard && standard > bulk, "{bulk} {standard} {critical}");
        // Standard keeps the legacy unweighted semantics.
        assert_eq!(standard, 10.0);
        assert_eq!(priority_weighted_backlog([0, 0, 0]), 0.0);
    }

    /// Two versions of one model, 600 KB each, plus the unrelated cold.
    fn versioned_catalog() -> Vec<(String, u64)> {
        vec![
            ("m@v1".to_string(), 600_000),
            ("m@v2".to_string(), 600_000),
            ("cold".to_string(), 600_000),
        ]
    }

    #[test]
    fn retiring_version_drains_only_after_successor_is_warm() {
        let mut c = cfg();
        c.memory_budget_mb = 0.0;
        let mut core = PlacementCore::new(c, versioned_catalog());
        core.set_successor("m@v1", "m@v2");
        // Successor still mid-load: the retiring version's last warm copy
        // is pinned even though its floor is zero and it drains on sight.
        let views = vec![
            view_loading("i0", &["m@v1"], &[]),
            view_loading("i1", &["cold"], &["m@v2"]),
        ];
        let moves = core.plan(0.0, &views, &BTreeMap::new());
        assert!(
            !moves
                .iter()
                .any(|m| matches!(m, Move::Unload { model, .. } if model == "m@v1")),
            "unloaded the last warm copy before the successor was warm: {moves:?}"
        );
        // Successor warm somewhere: the retiring copy goes, demand or not.
        let views = vec![
            view_loading("i0", &["m@v1"], &[]),
            view_loading("i1", &["cold", "m@v2"], &[]),
        ];
        let moves = core.plan(10.0, &views, &BTreeMap::new());
        assert!(
            moves
                .iter()
                .any(|m| matches!(m, Move::Unload { instance, model }
                    if instance == "i0" && model == "m@v1")),
            "retiring version did not drain once the successor was warm: {moves:?}"
        );
        // And the drained version is never repaired back or grown again.
        let gone = vec![view("i0", &["cold"]), view("i1", &["cold", "m@v2"])];
        let demand: BTreeMap<String, f64> =
            [("m@v1".to_string(), 10_000.0)].into_iter().collect();
        let moves = core.plan(20.0, &gone, &demand);
        assert!(
            !moves
                .iter()
                .any(|m| matches!(m, Move::Load { model, .. } if model == "m@v1")),
            "retired version re-placed: {moves:?}"
        );
        // clear_successor restores the normal floor: the repair pass
        // re-hosts it again (a rolled-back canary can come back).
        assert!(core.clear_successor("m@v1"));
        assert!(!core.clear_successor("m@v1"));
        let moves = core.plan(30.0, &gone, &BTreeMap::new());
        assert!(
            moves
                .iter()
                .any(|m| matches!(m, Move::Load { model, .. } if model == "m@v1")),
            "cleared successor did not restore the floor: {moves:?}"
        );
    }

    #[test]
    fn repair_may_evict_retiring_version_with_warm_successor() {
        // Full fleet; cold lost its replica. The only safe victim is the
        // retiring m@v1 — its successor m@v2 is warm elsewhere, so even
        // its *last* warm copy is fair game for the repair eviction.
        let mut core = PlacementCore::new(cfg(), versioned_catalog());
        core.set_successor("m@v1", "m@v2");
        let views = vec![view("i0", &["m@v1"]), view("i1", &["m@v2"])];
        let moves = core.plan(0.0, &views, &BTreeMap::new());
        assert!(
            moves.iter().any(|m| matches!(m, Move::Unload { model, .. } if model == "m@v1")),
            "{moves:?}"
        );
        assert!(
            moves.iter().any(|m| matches!(m, Move::Load { model, .. } if model == "cold")),
            "{moves:?}"
        );
    }

    #[test]
    fn demand_for_aggregates_versions_of_a_name() {
        use crate::config::LbPolicy;

        let registry = Registry::new();
        let names = ["m@v1".to_string(), "m@v2".to_string()];
        let router =
            Arc::new(ModelRouter::new(&names, LbPolicy::RoundRobin, 0, &registry, 7));
        let store = MetricStore::new(Duration::from_secs(60));
        // Cumulative routed-request counters for both versions: 10/s on
        // the incumbent, 2/s on the canary over the 10 s demand window.
        for (name, rate) in [("m@v1", 10.0), ("m@v2", 2.0)] {
            let series = format!("routed_requests_total{{model=\"{name}\"}}");
            store.push(&series, 0.0, 0.0);
            store.push(&series, 10.0, rate * 10.0);
        }
        let catalog: Vec<(String, u64)> =
            names.iter().map(|n| (n.clone(), 1)).collect();
        let controller = PlacementController::new(
            cfg(),
            catalog,
            BTreeMap::new(),
            BTreeMap::new(),
            1.0,
            router,
            store,
            Clock::real(),
            &registry,
        );
        // Per-version signals stay exact...
        assert!((controller.demand_for("m@v1", 10.0) - 10.0).abs() < 1e-9);
        assert!((controller.demand_for("m@v2", 10.0) - 2.0).abs() < 1e-9);
        // ...and the bare name the pod scaler asks about sees their sum,
        // not zero (the version-blindness fix).
        assert!((controller.demand_for("m", 10.0) - 12.0).abs() < 1e-9);
    }

    #[test]
    fn repair_gives_up_when_nothing_can_host() {
        // Single instance, both models at min=1 except... the cold model
        // has nowhere to go: the only other-model copy is NOT surplus.
        let mut core = PlacementCore::new(cfg(), catalog());
        let views = vec![view("i0", &["hot"])];
        let moves = core.plan(0.0, &views, &demand(30.0, 5.0));
        // hot is the last replica of its model: not evictable; cold stays
        // un-hosted rather than killing hot.
        assert!(
            !moves.iter().any(|m| matches!(m, Move::Unload { model, .. } if model == "hot")),
            "evicted a last replica: {moves:?}"
        );
        assert!(
            !moves.iter().any(|m| matches!(m, Move::Load { model, .. } if model == "cold")),
            "loaded cold with no memory for it: {moves:?}"
        );
    }
}
