//! Per-model routing table: one load balancer per model, address pools
//! that follow the instances' advertised-model labels.
//!
//! "Instead of using a single load balancer over all Triton servers,
//! inference requests will be routed via model-specific load balancers
//! across only those Triton servers where a given model is loaded."
//! Pools are created for the full model catalog at construction; a
//! request for a model outside the catalog is `ModelNotFound`, a request
//! for a catalog model with no (or only saturated) replicas is shed as
//! `Overloaded` — exactly what the single-balancer gateway reports today.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::config::LbPolicy;
use crate::gateway::lb::LoadBalancer;
use crate::metrics::registry::{labels, Counter, Registry};
use crate::rpc::codec::Status;
use crate::server::{split_version, Instance, InstanceState};

struct Pool {
    /// Live endpoint list, shared with this model's balancer.
    endpoints: Arc<RwLock<Vec<Arc<Instance>>>>,
    lb: LoadBalancer,
    /// Requests routed through this pool (per-model routed counter).
    routed: Counter,
    /// Requests that found no routable replica (shed at the router).
    unserved: Counter,
}

/// An active canary split for one base model name: `weight` of traffic
/// goes to `canary`, the rest to `incumbent` (both versioned names).
struct CanaryRoute {
    incumbent: String,
    canary: String,
    weight: f64,
    /// Per-request sequence hashed into the split decision so the
    /// traffic fraction is deterministic for a fixed seed yet free of
    /// the phase-locking a plain round-robin modulus would exhibit.
    seq: AtomicU64,
    seed: u64,
}

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// The model-aware routing table.
pub struct ModelRouter {
    pools: BTreeMap<String, Pool>,
    /// Base name -> incumbent versioned name (where unversioned client
    /// requests land when no canary/pin applies).
    defaults: RwLock<BTreeMap<String, String>>,
    /// Base name -> active canary split.
    canary: RwLock<BTreeMap<String, CanaryRoute>>,
    /// Base name -> operator-pinned versioned name (overrides both the
    /// default and any canary split — the config escape hatch).
    pinned: RwLock<BTreeMap<String, String>>,
}

impl ModelRouter {
    /// Router over `catalog` (every model the deployment can serve).
    /// Each pool gets its own balancer with the gateway's policy and
    /// in-flight cap; `seed` derives per-pool balancer seeds.
    pub fn new(
        catalog: &[String],
        policy: LbPolicy,
        max_inflight: usize,
        registry: &Registry,
        seed: u64,
    ) -> Self {
        Self::new_inner(catalog, policy, max_inflight, registry, seed, None)
    }

    /// [`ModelRouter::new`] for one federation site: the per-model
    /// routed/unserved counters gain a `site` label, so each site's
    /// demand signal (and the global rebalancer reading it) stays
    /// separable from the other sites'.
    pub fn new_for_site(
        catalog: &[String],
        policy: LbPolicy,
        max_inflight: usize,
        registry: &Registry,
        seed: u64,
        site: &str,
    ) -> Self {
        Self::new_inner(catalog, policy, max_inflight, registry, seed, Some(site))
    }

    fn new_inner(
        catalog: &[String],
        policy: LbPolicy,
        max_inflight: usize,
        registry: &Registry,
        seed: u64,
        site: Option<&str>,
    ) -> Self {
        let mut pools = BTreeMap::new();
        for (i, model) in catalog.iter().enumerate() {
            let endpoints = Arc::new(RwLock::new(Vec::new()));
            let lb = LoadBalancer::new(
                policy,
                Arc::clone(&endpoints),
                max_inflight,
                seed ^ ((i as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)),
            );
            let l = match site {
                None => labels(&[("model", model)]),
                Some(site) => labels(&[("model", model), ("site", site)]),
            };
            pools.insert(
                model.clone(),
                Pool {
                    endpoints,
                    lb,
                    routed: registry.counter("routed_requests_total", &l),
                    unserved: registry.counter("routed_unserved_total", &l),
                },
            );
        }
        ModelRouter {
            pools,
            defaults: RwLock::new(BTreeMap::new()),
            canary: RwLock::new(BTreeMap::new()),
            pinned: RwLock::new(BTreeMap::new()),
        }
    }

    /// Models in the catalog.
    pub fn models(&self) -> Vec<String> {
        self.pools.keys().cloned().collect()
    }

    /// Route unversioned requests for `base` to `versioned` (the
    /// incumbent). Called at boot and again on canary promotion.
    pub fn set_version_default(&self, base: &str, versioned: &str) {
        self.defaults
            .write()
            .unwrap()
            .insert(base.to_string(), versioned.to_string());
    }

    /// Install a canary split for `base`: `weight` of traffic to
    /// `canary`, the rest to `incumbent`. Replaces any existing split.
    pub fn set_canary(&self, base: &str, incumbent: &str, canary: &str, weight: f64, seed: u64) {
        self.canary.write().unwrap().insert(
            base.to_string(),
            CanaryRoute {
                incumbent: incumbent.to_string(),
                canary: canary.to_string(),
                weight,
                seq: AtomicU64::new(0),
                seed,
            },
        );
    }

    /// Tear down the canary split for `base` (rollback or promotion).
    /// Returns false if no split was active.
    pub fn clear_canary(&self, base: &str) -> bool {
        self.canary.write().unwrap().remove(base).is_some()
    }

    /// The active split for `base` as (incumbent, canary, weight).
    pub fn canary_of(&self, base: &str) -> Option<(String, String, f64)> {
        self.canary
            .read()
            .unwrap()
            .get(base)
            .map(|r| (r.incumbent.clone(), r.canary.clone(), r.weight))
    }

    /// Pin all traffic for `base` to `versioned`, overriding the
    /// default and any canary split (operator override from config).
    pub fn pin_version(&self, base: &str, versioned: &str) {
        self.pinned
            .write()
            .unwrap()
            .insert(base.to_string(), versioned.to_string());
    }

    /// Resolve a client-facing model name to the concrete versioned
    /// pool it should hit. Versioned requests pass through untouched;
    /// unversioned requests walk pinned -> canary split -> incumbent
    /// default, falling past any choice whose pool currently has no
    /// warm replica to the next one — and, last, to *any* version of
    /// the base with a live pool — so a mid-swap rollout never turns
    /// into `ModelNotFound` while some version is warm somewhere.
    pub fn resolve(&self, name: &str) -> String {
        self.resolve_with(name, &|pool| self.replicas(pool))
    }

    /// [`ModelRouter::resolve`] with an injected warm-replica probe.
    /// The federation router resolves on its policy router but probes
    /// warm counts summed over *all* sites, so a version drained at one
    /// site keeps resolving while it is warm anywhere in the federation.
    pub fn resolve_with(&self, name: &str, warm: &dyn Fn(&str) -> usize) -> String {
        if split_version(name).1.is_some() {
            return name.to_string();
        }
        if let Some(p) = self.pinned.read().unwrap().get(name) {
            return p.clone();
        }
        if let Some(route) = self.canary.read().unwrap().get(name) {
            let n = route.seq.fetch_add(1, Ordering::Relaxed);
            let frac = (splitmix64(n ^ route.seed) >> 11) as f64 / (1u64 << 53) as f64;
            let (first, second) = if frac < route.weight {
                (&route.canary, &route.incumbent)
            } else {
                (&route.incumbent, &route.canary)
            };
            if warm(first) > 0 {
                return first.clone();
            }
            if warm(second) > 0 {
                return second.clone();
            }
        }
        let default = self.defaults.read().unwrap().get(name).cloned();
        if let Some(d) = &default {
            if warm(d) > 0 {
                return d.clone();
            }
            // Default drained mid-swap: any warm version of the base
            // keeps serving rather than shedding.
            for pool_name in self.pools.keys() {
                if split_version(pool_name).0 == name && warm(pool_name) > 0 {
                    return pool_name.clone();
                }
            }
            return d.clone();
        }
        name.to_string()
    }

    /// Pick an instance for one request to `model`. `Err(ModelNotFound)`
    /// when the model is outside the catalog, `Err(Overloaded)` when its
    /// pool has no routable replica.
    pub fn pick(&self, model: &str) -> Result<Arc<Instance>, Status> {
        self.pick_excluding(model, None)
    }

    /// [`ModelRouter::pick`] skipping the replica named `exclude` — the
    /// gateway's retry path, which must land on a *different* replica
    /// than the one that just rejected the request (the rejecting
    /// replica's queue is full or its pool entry is stale; re-picking it
    /// would fail identically).
    pub fn pick_excluding(
        &self,
        model: &str,
        exclude: Option<&str>,
    ) -> Result<Arc<Instance>, Status> {
        let Some(pool) = self.pools.get(model) else {
            return Err(Status::ModelNotFound);
        };
        pool.routed.inc();
        match pool.lb.pick_excluding(exclude) {
            Some(inst) => Ok(inst),
            None => {
                pool.unserved.inc();
                Err(Status::Overloaded)
            }
        }
    }

    /// Load `model` onto `instance`: label first, then pool membership,
    /// so the pool never references a non-advertising instance. With a
    /// warm-load delay configured the instance enters `Loading` and the
    /// pool is NOT touched here — the reconcile-driven [`ModelRouter::sync`]
    /// admits it once the model turns warm (loading replicas never
    /// receive traffic). Returns false if the model is unknown (to the
    /// catalog or the instance's repository) or already in the
    /// instance's serving set.
    pub fn load(&self, instance: &Arc<Instance>, model: &str) -> bool {
        let Some(pool) = self.pools.get(model) else {
            return false;
        };
        if !instance.load_model(model) {
            return false;
        }
        if instance.advertises(model) {
            let mut eps = pool.endpoints.write().unwrap();
            if !eps.iter().any(|e| e.id == instance.id) {
                eps.push(Arc::clone(instance));
            }
        }
        true
    }

    /// Unload `model` from `instance`: pool membership first, then the
    /// label. Returns false if it was not loaded there.
    pub fn unload(&self, instance: &Arc<Instance>, model: &str) -> bool {
        let Some(pool) = self.pools.get(model) else {
            return false;
        };
        pool.endpoints
            .write()
            .unwrap()
            .retain(|e| e.id != instance.id);
        instance.unload_model(model)
    }

    /// Rebuild every pool from the instances' advertised sets — the
    /// label-watch half of the design ("load balancers automatically
    /// adjust address pools when models are loaded and unloaded").
    /// Driven by the cluster reconcile loop so pod churn (new Running
    /// pods, terminated pods) and `Loading -> warm` transitions are
    /// reflected within one reconcile period; replicas mid-load are
    /// excluded until warm.
    pub fn sync(&self, endpoints: &[Arc<Instance>]) {
        for (model, pool) in &self.pools {
            let members: Vec<Arc<Instance>> = endpoints
                .iter()
                .filter(|i| i.advertises(model))
                .cloned()
                .collect();
            *pool.endpoints.write().unwrap() = members;
        }
    }

    /// Whether `model` is in this router's catalog (has a pool). The
    /// federation router uses this to tell "unknown model" from "known
    /// but nowhere warm" when every site comes up empty.
    pub fn serves(&self, model: &str) -> bool {
        self.pools.contains_key(model)
    }

    /// Instances currently in `model`'s pool (replica count source).
    pub fn endpoints_for(&self, model: &str) -> Vec<Arc<Instance>> {
        self.pools
            .get(model)
            .map(|p| p.endpoints.read().unwrap().clone())
            .unwrap_or_default()
    }

    /// Replica count of one model's pool.
    pub fn replicas(&self, model: &str) -> usize {
        self.pools
            .get(model)
            .map(|p| p.endpoints.read().unwrap().len())
            .unwrap_or(0)
    }

    /// Distinct Ready instances across all pools (the health-probe
    /// answer: is anything routable for at least one model).
    pub fn ready_instances(&self) -> usize {
        let mut seen = BTreeSet::new();
        for pool in self.pools.values() {
            for inst in pool.endpoints.read().unwrap().iter() {
                if inst.state() == InstanceState::Ready {
                    seen.insert(inst.id.clone());
                }
            }
        }
        seen.len()
    }

    /// Total requests routed per model (for experiments/benches).
    pub fn routed_count(&self, model: &str) -> u64 {
        self.pools.get(model).map(|p| p.routed.get()).unwrap_or(0)
    }

    /// Requests shed at the router per model.
    pub fn unserved_count(&self, model: &str) -> u64 {
        self.pools.get(model).map(|p| p.unserved.get()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExecutionMode, ModelConfig, ServiceModelConfig};
    use crate::server::ModelRepository;
    use crate::util::clock::Clock;
    use once_cell::sync::Lazy;
    use std::time::Duration;

    const MODELS: [&str; 2] = ["icecube_cnn", "particlenet"];

    static REPO: Lazy<Arc<ModelRepository>> = Lazy::new(|| {
        Arc::new(
            ModelRepository::load_metadata(
                std::path::Path::new("artifacts"),
                &MODELS.map(String::from),
            )
            .unwrap(),
        )
    });

    fn instance(id: &str) -> Arc<Instance> {
        let models: Vec<ModelConfig> = MODELS
            .iter()
            .map(|m| ModelConfig {
                name: m.to_string(),
                max_queue_delay: Duration::from_millis(1),
                preferred_batch: 8,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(2),
                    per_row: Duration::from_micros(100),
                },
                load_delay: None,
                backends: Vec::new(),
                ..ModelConfig::default()
            })
            .collect();
        let inst = Instance::start_with_mode(
            id,
            Arc::clone(&REPO),
            &models,
            Clock::real(),
            Registry::new(),
            64,
            5.0,
            ExecutionMode::Simulated,
        );
        inst.mark_ready();
        inst
    }

    fn catalog() -> Vec<String> {
        MODELS.map(String::from).to_vec()
    }

    fn router() -> ModelRouter {
        ModelRouter::new(&catalog(), LbPolicy::RoundRobin, 0, &Registry::new(), 7)
    }

    #[test]
    fn pick_unknown_model_not_found() {
        let r = router();
        assert!(matches!(r.pick("nope"), Err(Status::ModelNotFound)));
    }

    #[test]
    fn empty_pool_overloaded() {
        let r = router();
        assert!(matches!(r.pick("icecube_cnn"), Err(Status::Overloaded)));
        assert_eq!(r.unserved_count("icecube_cnn"), 1);
    }

    #[test]
    fn pick_excluding_skips_rejecting_replica() {
        let r = router();
        let a = instance("px-a");
        let b = instance("px-b");
        r.sync(&[Arc::clone(&a), Arc::clone(&b)]);
        // The retry path never re-picks the replica that just rejected.
        for _ in 0..4 {
            let picked = r.pick_excluding("icecube_cnn", Some(a.id.as_str())).unwrap();
            assert_eq!(picked.id, b.id);
        }
        assert_eq!(r.pick_excluding("icecube_cnn", Some(b.id.as_str())).unwrap().id, a.id);
        // A single-replica pool whose replica is excluded sheds instead
        // of handing the rejecting instance straight back.
        r.sync(&[Arc::clone(&a)]);
        assert!(matches!(
            r.pick_excluding("icecube_cnn", Some(a.id.as_str())),
            Err(Status::Overloaded)
        ));
        a.stop();
        b.stop();
    }

    #[test]
    fn routes_only_to_pool_members() {
        let r = router();
        let a = instance("ra");
        let b = instance("rb");
        // a serves only the cnn, b serves only particlenet
        r.sync(&[Arc::clone(&a), Arc::clone(&b)]);
        r.unload(&a, "particlenet");
        r.unload(&b, "icecube_cnn");
        for _ in 0..6 {
            assert_eq!(r.pick("icecube_cnn").unwrap().id, "ra");
            assert_eq!(r.pick("particlenet").unwrap().id, "rb");
        }
        assert_eq!(r.routed_count("icecube_cnn"), 6);
        a.stop();
        b.stop();
    }

    #[test]
    fn load_updates_pool_and_label() {
        let r = router();
        let a = instance("rl");
        a.set_loaded_models(&[]);
        r.sync(&[Arc::clone(&a)]);
        assert_eq!(r.replicas("icecube_cnn"), 0);
        assert!(r.load(&a, "icecube_cnn"));
        assert!(a.advertises("icecube_cnn"));
        assert_eq!(r.replicas("icecube_cnn"), 1);
        // idempotent
        assert!(!r.load(&a, "icecube_cnn"));
        assert_eq!(r.replicas("icecube_cnn"), 1);
        assert!(r.unload(&a, "icecube_cnn"));
        assert!(!a.advertises("icecube_cnn"));
        assert_eq!(r.replicas("icecube_cnn"), 0);
        a.stop();
    }

    fn slow_load_instance(id: &str, delay: Duration) -> Arc<Instance> {
        let models: Vec<ModelConfig> = MODELS
            .iter()
            .map(|m| ModelConfig {
                name: m.to_string(),
                max_queue_delay: Duration::from_millis(1),
                preferred_batch: 8,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(2),
                    per_row: Duration::from_micros(100),
                },
                load_delay: Some(delay),
                backends: Vec::new(),
                ..ModelConfig::default()
            })
            .collect();
        let inst = Instance::start_with_mode(
            id,
            Arc::clone(&REPO),
            &models,
            Clock::real(),
            Registry::new(),
            64,
            5.0,
            ExecutionMode::Simulated,
        );
        inst.mark_ready();
        inst
    }

    #[test]
    fn loading_replica_excluded_until_warm() {
        let r = router();
        let a = slow_load_instance("rw0", Duration::from_millis(150));
        a.set_loaded_models(&[]);
        r.sync(&[Arc::clone(&a)]);
        // the load starts the warm window but must NOT join the pool
        assert!(r.load(&a, "icecube_cnn"));
        assert!(a.is_loading("icecube_cnn"));
        assert_eq!(r.replicas("icecube_cnn"), 0);
        assert!(matches!(r.pick("icecube_cnn"), Err(Status::Overloaded)));
        // mid-window syncs keep it out
        r.sync(&[Arc::clone(&a)]);
        assert_eq!(r.replicas("icecube_cnn"), 0);
        // once warm, the next sync admits it
        std::thread::sleep(Duration::from_millis(180));
        r.sync(&[Arc::clone(&a)]);
        assert_eq!(r.replicas("icecube_cnn"), 1);
        assert_eq!(r.pick("icecube_cnn").unwrap().id, "rw0");
        a.stop();
    }

    #[test]
    fn resolve_walks_version_chain() {
        REPO.register_version("icecube_cnn", 1).unwrap();
        REPO.register_version("icecube_cnn", 2).unwrap();
        let mut cat = catalog();
        cat.push("icecube_cnn@v1".into());
        cat.push("icecube_cnn@v2".into());
        let r = ModelRouter::new(&cat, LbPolicy::RoundRobin, 0, &Registry::new(), 7);
        // unversioned name with no default passes through untouched
        assert_eq!(r.resolve("particlenet"), "particlenet");
        // versioned requests are never rewritten
        assert_eq!(r.resolve("icecube_cnn@v2"), "icecube_cnn@v2");
        r.set_version_default("icecube_cnn", "icecube_cnn@v1");
        // nothing warm anywhere: resolve still lands on the default so
        // the request sheds Overloaded, not ModelNotFound
        assert_eq!(r.resolve("icecube_cnn"), "icecube_cnn@v1");
        let a = instance("rv-a");
        a.set_loaded_models(&["icecube_cnn@v1".to_string()]);
        r.sync(&[Arc::clone(&a)]);
        assert_eq!(r.resolve("icecube_cnn"), "icecube_cnn@v1");
        // canary installed but not yet warm: every request falls back
        // to the incumbent — no shed spike while the canary loads
        r.set_canary("icecube_cnn", "icecube_cnn@v1", "icecube_cnn@v2", 0.25, 42);
        for _ in 0..64 {
            assert_eq!(r.resolve("icecube_cnn"), "icecube_cnn@v1");
        }
        // canary warm: the split tracks the configured weight
        let b = instance("rv-b");
        b.set_loaded_models(&["icecube_cnn@v2".to_string()]);
        r.sync(&[Arc::clone(&a), Arc::clone(&b)]);
        let hits = (0..4000)
            .filter(|_| r.resolve("icecube_cnn") == "icecube_cnn@v2")
            .count();
        assert!((800..1200).contains(&hits), "canary fraction {hits}/4000");
        // incumbent drained mid-swap: the split keeps serving from the
        // canary side instead of shedding
        r.sync(&[Arc::clone(&b)]);
        assert_eq!(r.resolve("icecube_cnn"), "icecube_cnn@v2");
        assert!(r.clear_canary("icecube_cnn"));
        assert!(!r.clear_canary("icecube_cnn"));
        // default drained but v2 warm: fall to any warm version of the base
        assert_eq!(r.resolve("icecube_cnn"), "icecube_cnn@v2");
        // pin overrides everything
        r.pin_version("icecube_cnn", "icecube_cnn@v1");
        assert_eq!(r.resolve("icecube_cnn"), "icecube_cnn@v1");
        a.stop();
        b.stop();
    }

    #[test]
    fn sync_follows_pod_churn() {
        let r = router();
        let a = instance("rs0");
        let b = instance("rs1");
        r.sync(&[Arc::clone(&a), Arc::clone(&b)]);
        assert_eq!(r.replicas("icecube_cnn"), 2);
        assert_eq!(r.ready_instances(), 2);
        // pod terminated: drops from every pool on the next sync
        r.sync(&[Arc::clone(&a)]);
        assert_eq!(r.replicas("icecube_cnn"), 1);
        assert_eq!(r.replicas("particlenet"), 1);
        a.stop();
        b.stop();
    }
}
