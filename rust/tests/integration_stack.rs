//! Integration tests over the full deployment stack: config file →
//! Deployment::up → cluster → gateway → instances → PJRT / simulated
//! executors, exercised over real TCP.

use std::sync::Arc;
use std::time::Duration;

use supersonic::config::{
    AutoscalerConfig, ClusterConfig, DeploymentConfig, ExecutionMode, GatewayConfig,
    ModelConfig, MonitoringConfig, ServerConfig, ServiceModelConfig,
};
use supersonic::deployment::Deployment;
use supersonic::gateway::auth;
use supersonic::rpc::client::RpcClient;
use supersonic::rpc::codec::Status;
use supersonic::runtime::Tensor;
use supersonic::workload::{ClientPool, Schedule, WorkloadSpec};

fn base_cfg(execution: ExecutionMode) -> DeploymentConfig {
    DeploymentConfig {
        name: "itest".into(),
        server: ServerConfig {
            replicas: 2,
            models: vec![ModelConfig {
                name: "icecube_cnn".into(),
                max_queue_delay: Duration::from_millis(1),
                preferred_batch: 8,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(2),
                    per_row: Duration::from_micros(100),
                },
                load_delay: None,
                backends: Vec::new(),
            }],
            repository: "artifacts".into(),
            startup_delay: Duration::from_millis(10),
            execution,
            queue_capacity: 128,
            util_window: 5.0,
            batch_mode: Default::default(),
            priorities: Default::default(),
        },
        gateway: GatewayConfig::default(),
        autoscaler: AutoscalerConfig { enabled: false, max_replicas: 6, ..Default::default() },
        cluster: ClusterConfig {
            nodes: 3,
            gpus_per_node: 2,
            pod_start_delay: Duration::from_millis(20),
            termination_grace: Duration::from_millis(20),
            pod_failure_rate: 0.0,
        },
        monitoring: MonitoringConfig {
            listen: String::new(),
            scrape_interval: Duration::from_millis(100),
            retention: Duration::from_secs(600),
            tracing: false,
        },
        model_placement: Default::default(),
        engines: Default::default(),
        observability: Default::default(),
        time_scale: 1.0,
    }
}

fn cnn(rows: usize) -> Tensor {
    Tensor::zeros(vec![rows, 16, 16, 3])
}

#[test]
fn full_stack_serves_under_concurrency() {
    let d = Deployment::up(base_cfg(ExecutionMode::Simulated)).unwrap();
    assert!(d.wait_ready(2, Duration::from_secs(10)));
    let addr = d.endpoint();
    let mut handles = Vec::new();
    for _ in 0..6 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = RpcClient::connect(&addr).unwrap();
            let mut ok = 0;
            for rows in [1usize, 3, 8, 17] {
                let resp = client.infer("icecube_cnn", cnn(rows)).unwrap();
                if resp.status == Status::Ok && resp.output.shape() == [rows, 3] {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 24);
    d.down();
}

#[test]
fn scale_up_down_serves_during_transition() {
    let d = Deployment::up(base_cfg(ExecutionMode::Simulated)).unwrap();
    assert!(d.wait_ready(2, Duration::from_secs(10)));

    // Continuous load while the cluster rescales 2 -> 5 -> 1.
    let spec = WorkloadSpec::new("icecube_cnn", 2, vec![16, 16, 3]);
    let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
    let cluster = Arc::clone(&d.cluster);
    let driver = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(200));
        cluster.set_desired(5);
        std::thread::sleep(Duration::from_millis(600));
        cluster.set_desired(1);
    });
    let report = pool.run(&Schedule::constant(4, Duration::from_millis(1500)));
    driver.join().unwrap();

    assert!(report.total_ok > 50, "ok={}", report.total_ok);
    assert_eq!(report.total_errors, 0, "errors during rescale");
    // After scale-down completes the cluster converges to 1.
    let t0 = std::time::Instant::now();
    while d.cluster.running() != 1 && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(d.cluster.running(), 1);
    d.down();
}

#[test]
fn autoscaler_reacts_to_load_spike_end_to_end() {
    let mut cfg = base_cfg(ExecutionMode::Simulated);
    cfg.server.replicas = 1;
    cfg.server.models[0].service_model = ServiceModelConfig {
        base: Duration::from_millis(20),
        per_row: Duration::from_millis(1),
    };
    cfg.autoscaler = AutoscalerConfig {
        enabled: true,
        metric: "queue_latency_avg:2".into(),
        threshold: 0.015,
        scale_down_ratio: 0.2,
        min_replicas: 1,
        max_replicas: 4,
        poll_interval: Duration::from_millis(100),
        scale_up_cooldown: Duration::from_millis(300),
        scale_down_stabilization: Duration::from_secs(60),
        step: 1,
        per_model: Default::default(),
    };
    cfg.monitoring.scrape_interval = Duration::from_millis(50);
    let d = Deployment::up(cfg).unwrap();
    assert!(d.wait_ready(1, Duration::from_secs(10)));

    // 8 closed-loop clients on a 22ms-per-batch server: sustained queueing.
    let spec = WorkloadSpec::new("icecube_cnn", 2, vec![16, 16, 3]);
    let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
    let report = pool.run(&Schedule::constant(8, Duration::from_secs(6)));
    assert!(report.total_ok > 0);
    assert!(
        d.cluster.desired() > 1,
        "autoscaler never scaled up (desired={}, metric={})",
        d.cluster.desired(),
        d.autoscaler.metric_value()
    );
    d.down();
}

#[test]
#[cfg_attr(
    not(feature = "pjrt"),
    ignore = "needs compiled PJRT engines: build with --features pjrt after `make artifacts`"
)]
fn real_pjrt_numerics_through_full_stack() {
    let mut cfg = base_cfg(ExecutionMode::Real);
    cfg.server.replicas = 1;
    let d = Deployment::up(cfg).unwrap();
    assert!(d.wait_ready(1, Duration::from_secs(15)));
    let g = supersonic::runtime::golden::load(std::path::Path::new(
        "artifacts/icecube_cnn/golden.b8.txt",
    ))
    .unwrap();
    let mut client = RpcClient::connect(&d.endpoint()).unwrap();
    let resp = client.infer("icecube_cnn", g.input.clone()).unwrap();
    assert_eq!(resp.status, Status::Ok, "{}", resp.error);
    let diff = resp.output.max_abs_diff(&g.output).unwrap();
    assert!(diff < 1e-3, "numerics mismatch over the wire: {diff}");
    d.down();
}

#[test]
fn auth_and_rate_limit_full_stack() {
    let mut cfg = base_cfg(ExecutionMode::Simulated);
    cfg.gateway.auth_secret = Some("integration-secret".into());
    cfg.gateway.rate_limit_rps = 50.0;
    cfg.gateway.rate_limit_burst = 5;
    let d = Deployment::up(cfg).unwrap();
    assert!(d.wait_ready(2, Duration::from_secs(10)));

    // unauthenticated rejected
    let mut anon = RpcClient::connect(&d.endpoint()).unwrap();
    assert_eq!(anon.infer("icecube_cnn", cnn(1)).unwrap().status, Status::Unauthorized);

    // authenticated served, but a tight loop trips the limiter
    let token = auth::mint_token("integration-secret");
    let mut client = RpcClient::connect(&d.endpoint()).unwrap().with_token(&token);
    let mut ok = 0;
    let mut limited = 0;
    for _ in 0..40 {
        match client.infer("icecube_cnn", cnn(1)).unwrap().status {
            Status::Ok => ok += 1,
            Status::RateLimited => limited += 1,
            other => panic!("unexpected status {other:?}"),
        }
    }
    assert!(ok > 0, "no requests served");
    assert!(limited > 0, "rate limiter never tripped");
    d.down();
}

#[test]
fn pod_failures_recovered_under_load() {
    let mut cfg = base_cfg(ExecutionMode::Simulated);
    cfg.cluster.pod_failure_rate = 0.4;
    let d = Deployment::up(cfg).unwrap();
    // with retries, replicas eventually come up despite 40% start failures
    assert!(d.wait_ready(2, Duration::from_secs(20)));
    let spec = WorkloadSpec::new("icecube_cnn", 1, vec![16, 16, 3]);
    let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
    let report = pool.run(&Schedule::constant(2, Duration::from_millis(500)));
    assert!(report.total_ok > 0);
    assert_eq!(report.total_errors, 0);
    d.down();
}

#[test]
fn metrics_pipeline_end_to_end() {
    let mut cfg = base_cfg(ExecutionMode::Simulated);
    cfg.monitoring.listen = "127.0.0.1:0".into();
    let d = Deployment::up(cfg).unwrap();
    assert!(d.wait_ready(2, Duration::from_secs(10)));

    let mut client = RpcClient::connect(&d.endpoint()).unwrap();
    for _ in 0..10 {
        assert_eq!(client.infer("icecube_cnn", cnn(2)).unwrap().status, Status::Ok);
    }
    std::thread::sleep(Duration::from_millis(400)); // let the scraper run

    // Prometheus text endpoint includes request counters and utilization.
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(d.metrics_endpoint().unwrap()).unwrap();
    s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
    let mut body = String::new();
    s.read_to_string(&mut body).unwrap();
    assert!(body.contains("inference_requests_total"), "{body}");
    assert!(body.contains("gateway_requests_total"));

    // The store has windowed series the autoscaler queries.
    assert!(d.store.latest("replicas_running").is_some());
    assert!(d
        .store
        .series_ids()
        .iter()
        .any(|id| id.starts_with("request_queue_seconds{") && id.ends_with(":sum")));
    d.down();
}

#[test]
#[cfg_attr(
    not(feature = "pjrt"),
    ignore = "needs compiled PJRT engines: build with --features pjrt after `make artifacts`"
)]
fn multi_model_repository_served_real() {
    let mut cfg = base_cfg(ExecutionMode::Real);
    cfg.server.replicas = 1;
    cfg.server.models = vec![
        ModelConfig { name: "icecube_cnn".into(), ..ModelConfig::default() },
        ModelConfig { name: "cms_transformer".into(), ..ModelConfig::default() },
    ];
    let d = Deployment::up(cfg).unwrap();
    assert!(d.wait_ready(1, Duration::from_secs(15)));
    let mut client = RpcClient::connect(&d.endpoint()).unwrap();
    let r1 = client.infer("icecube_cnn", cnn(2)).unwrap();
    assert_eq!(r1.status, Status::Ok);
    assert_eq!(r1.output.shape(), &[2, 3]);
    let r2 = client
        .infer("cms_transformer", Tensor::zeros(vec![2, 32, 32]))
        .unwrap();
    assert_eq!(r2.status, Status::Ok, "{}", r2.error);
    assert_eq!(r2.output.shape(), &[2, 2]);
    // unknown model still 404s
    assert_eq!(client.infer("nope", cnn(1)).unwrap().status, Status::ModelNotFound);
    d.down();
}
