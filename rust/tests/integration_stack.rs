//! Integration tests over the full deployment stack: config file →
//! Deployment::up → cluster → gateway → instances → PJRT / simulated
//! executors, exercised over real TCP.

use std::sync::Arc;
use std::time::Duration;

use supersonic::config::{
    AutoscalerConfig, ClusterConfig, DeploymentConfig, ExecutionMode, GatewayConfig,
    ModelConfig, MonitoringConfig, ServerConfig, ServiceModelConfig,
};
use supersonic::deployment::Deployment;
use supersonic::gateway::auth;
use supersonic::rpc::client::RpcClient;
use supersonic::rpc::codec::{InferRequest, Status};
use supersonic::rpc::{RpcSession, SessionOpts};
use supersonic::runtime::Tensor;
use supersonic::workload::{ClientPool, Schedule, WorkloadSpec};

fn base_cfg(execution: ExecutionMode) -> DeploymentConfig {
    DeploymentConfig {
        name: "itest".into(),
        server: ServerConfig {
            replicas: 2,
            models: vec![ModelConfig {
                name: "icecube_cnn".into(),
                max_queue_delay: Duration::from_millis(1),
                preferred_batch: 8,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(2),
                    per_row: Duration::from_micros(100),
                },
                load_delay: None,
                backends: Vec::new(),
                ..ModelConfig::default()
            }],
            repository: "artifacts".into(),
            startup_delay: Duration::from_millis(10),
            execution,
            queue_capacity: 128,
            util_window: 5.0,
            batch_mode: Default::default(),
            priorities: Default::default(),
        },
        gateway: GatewayConfig::default(),
        autoscaler: AutoscalerConfig { enabled: false, max_replicas: 6, ..Default::default() },
        cluster: ClusterConfig {
            nodes: 3,
            gpus_per_node: 2,
            pod_start_delay: Duration::from_millis(20),
            termination_grace: Duration::from_millis(20),
            pod_failure_rate: 0.0,
        },
        monitoring: MonitoringConfig {
            listen: String::new(),
            scrape_interval: Duration::from_millis(100),
            retention: Duration::from_secs(600),
            tracing: false,
        },
        model_placement: Default::default(),
        engines: Default::default(),
        observability: Default::default(),
        rpc: Default::default(),
        federation: Default::default(),
        time_scale: 1.0,
    }
}

fn cnn(rows: usize) -> Tensor {
    Tensor::zeros(vec![rows, 16, 16, 3])
}

#[test]
fn full_stack_serves_under_concurrency() {
    let d = Deployment::up(base_cfg(ExecutionMode::Simulated)).unwrap();
    assert!(d.wait_ready(2, Duration::from_secs(10)));
    let addr = d.endpoint();
    let mut handles = Vec::new();
    for _ in 0..6 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = RpcClient::connect(&addr).unwrap();
            let mut ok = 0;
            for rows in [1usize, 3, 8, 17] {
                let resp = client.infer("icecube_cnn", cnn(rows)).unwrap();
                if resp.status == Status::Ok && resp.output.shape() == [rows, 3] {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 24);
    d.down();
}

#[test]
fn multiplexed_session_no_cross_talk_under_concurrency() {
    // N threads x M pipelined requests on ONE shared TCP connection
    // through the real gateway stack, demultiplexed dispatch on. Every
    // request carries a distinguishable payload (its row count), and the
    // simulated executor answers [rows, 3] — so any response matched to
    // the wrong in-flight request shows up as a shape mismatch.
    let mut cfg = base_cfg(ExecutionMode::Simulated);
    cfg.rpc.dispatch_threads = 8;
    cfg.rpc.max_inflight_per_conn = 256;
    let d = Deployment::up(cfg).unwrap();
    assert!(d.wait_ready(2, Duration::from_secs(10)));

    let session = Arc::new(RpcSession::connect(&d.endpoint(), SessionOpts::default()).unwrap());
    let threads = 4;
    let per_thread = 24;
    let mut handles = Vec::new();
    for t in 0..threads {
        let session = Arc::clone(&session);
        handles.push(std::thread::spawn(move || {
            let rows_of = |j: usize| 1 + (t * per_thread + j) % 13;
            // Pipeline: submit the whole batch, then await the replies —
            // all M stay in flight together, interleaved with the other
            // threads' traffic on the same socket.
            let pending: Vec<_> = (0..per_thread)
                .map(|j| {
                    let req = InferRequest::infer(0, "icecube_cnn", cnn(rows_of(j)));
                    session.submit(&req).unwrap()
                })
                .collect();
            let mut mixups = 0;
            for (j, reply) in pending.into_iter().enumerate() {
                let resp = reply.wait().unwrap();
                assert_eq!(resp.status, Status::Ok, "{}", resp.error);
                if resp.output.shape() != [rows_of(j), 3] {
                    mixups += 1;
                }
            }
            mixups
        }));
    }
    let mixups: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(mixups, 0, "responses matched to the wrong in-flight request");
    d.down();
}

#[test]
fn remote_dispatch_stack_no_cross_talk() {
    // Same cross-talk property with the second hop enabled: client
    // session -> gateway -> pooled backend session -> instance RPC
    // endpoint. Request ids are restamped at each hop; payload shapes
    // prove the responses still come back to the right caller.
    let mut cfg = base_cfg(ExecutionMode::Simulated);
    cfg.rpc.remote_dispatch = true;
    cfg.rpc.dispatch_threads = 4;
    cfg.rpc.max_inflight_per_conn = 64;
    cfg.rpc.pool_size = 2;
    let d = Deployment::up(cfg).unwrap();
    assert!(d.wait_ready(2, Duration::from_secs(10)));

    let session = RpcSession::connect(&d.endpoint(), SessionOpts::default()).unwrap();
    let pending: Vec<_> = (0..32)
        .map(|j| {
            let req = InferRequest::infer(0, "icecube_cnn", cnn(1 + j % 13));
            session.submit(&req).unwrap()
        })
        .collect();
    for (j, reply) in pending.into_iter().enumerate() {
        let resp = reply.wait().unwrap();
        assert_eq!(resp.status, Status::Ok, "{}", resp.error);
        assert_eq!(resp.output.shape(), &[1 + j % 13, 3], "cross-request mixup at {j}");
    }
    let pool = d.gateway.session_pool().expect("remote dispatch enables the session pool");
    assert!(pool.connects() >= 1, "gateway never dialed a backend session");
    d.down();
}

#[test]
fn scale_up_down_serves_during_transition() {
    let d = Deployment::up(base_cfg(ExecutionMode::Simulated)).unwrap();
    assert!(d.wait_ready(2, Duration::from_secs(10)));

    // Continuous load while the cluster rescales 2 -> 5 -> 1.
    let spec = WorkloadSpec::new("icecube_cnn", 2, vec![16, 16, 3]);
    let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
    let cluster = Arc::clone(&d.cluster);
    let driver = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(200));
        cluster.set_desired(5);
        std::thread::sleep(Duration::from_millis(600));
        cluster.set_desired(1);
    });
    let report = pool.run(&Schedule::constant(4, Duration::from_millis(1500)));
    driver.join().unwrap();

    assert!(report.total_ok > 50, "ok={}", report.total_ok);
    assert_eq!(report.total_errors, 0, "errors during rescale");
    // After scale-down completes the cluster converges to 1.
    let t0 = std::time::Instant::now();
    while d.cluster.running() != 1 && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(d.cluster.running(), 1);
    d.down();
}

#[test]
fn autoscaler_reacts_to_load_spike_end_to_end() {
    let mut cfg = base_cfg(ExecutionMode::Simulated);
    cfg.server.replicas = 1;
    cfg.server.models[0].service_model = ServiceModelConfig {
        base: Duration::from_millis(20),
        per_row: Duration::from_millis(1),
    };
    cfg.autoscaler = AutoscalerConfig {
        enabled: true,
        metric: "queue_latency_avg:2".into(),
        threshold: 0.015,
        scale_down_ratio: 0.2,
        min_replicas: 1,
        max_replicas: 4,
        poll_interval: Duration::from_millis(100),
        scale_up_cooldown: Duration::from_millis(300),
        scale_down_stabilization: Duration::from_secs(60),
        step: 1,
        per_model: Default::default(),
    };
    cfg.monitoring.scrape_interval = Duration::from_millis(50);
    let d = Deployment::up(cfg).unwrap();
    assert!(d.wait_ready(1, Duration::from_secs(10)));

    // 8 closed-loop clients on a 22ms-per-batch server: sustained queueing.
    let spec = WorkloadSpec::new("icecube_cnn", 2, vec![16, 16, 3]);
    let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
    let report = pool.run(&Schedule::constant(8, Duration::from_secs(6)));
    assert!(report.total_ok > 0);
    assert!(
        d.cluster.desired() > 1,
        "autoscaler never scaled up (desired={}, metric={})",
        d.cluster.desired(),
        d.autoscaler.metric_value()
    );
    d.down();
}

#[test]
#[cfg_attr(
    not(feature = "pjrt"),
    ignore = "needs compiled PJRT engines: build with --features pjrt after `make artifacts`"
)]
fn real_pjrt_numerics_through_full_stack() {
    let mut cfg = base_cfg(ExecutionMode::Real);
    cfg.server.replicas = 1;
    let d = Deployment::up(cfg).unwrap();
    assert!(d.wait_ready(1, Duration::from_secs(15)));
    let g = supersonic::runtime::golden::load(std::path::Path::new(
        "artifacts/icecube_cnn/golden.b8.txt",
    ))
    .unwrap();
    let mut client = RpcClient::connect(&d.endpoint()).unwrap();
    let resp = client.infer("icecube_cnn", g.input.clone()).unwrap();
    assert_eq!(resp.status, Status::Ok, "{}", resp.error);
    let diff = resp.output.max_abs_diff(&g.output).unwrap();
    assert!(diff < 1e-3, "numerics mismatch over the wire: {diff}");
    d.down();
}

#[test]
fn auth_and_rate_limit_full_stack() {
    let mut cfg = base_cfg(ExecutionMode::Simulated);
    cfg.gateway.auth_secret = Some("integration-secret".into());
    cfg.gateway.rate_limit_rps = 50.0;
    cfg.gateway.rate_limit_burst = 5;
    let d = Deployment::up(cfg).unwrap();
    assert!(d.wait_ready(2, Duration::from_secs(10)));

    // unauthenticated rejected
    let mut anon = RpcClient::connect(&d.endpoint()).unwrap();
    assert_eq!(anon.infer("icecube_cnn", cnn(1)).unwrap().status, Status::Unauthorized);

    // authenticated served, but a tight loop trips the limiter
    let token = auth::mint_token("integration-secret");
    let mut client = RpcClient::connect(&d.endpoint()).unwrap().with_token(&token);
    let mut ok = 0;
    let mut limited = 0;
    for _ in 0..40 {
        match client.infer("icecube_cnn", cnn(1)).unwrap().status {
            Status::Ok => ok += 1,
            Status::RateLimited => limited += 1,
            other => panic!("unexpected status {other:?}"),
        }
    }
    assert!(ok > 0, "no requests served");
    assert!(limited > 0, "rate limiter never tripped");
    d.down();
}

#[test]
fn pod_failures_recovered_under_load() {
    let mut cfg = base_cfg(ExecutionMode::Simulated);
    cfg.cluster.pod_failure_rate = 0.4;
    let d = Deployment::up(cfg).unwrap();
    // with retries, replicas eventually come up despite 40% start failures
    assert!(d.wait_ready(2, Duration::from_secs(20)));
    let spec = WorkloadSpec::new("icecube_cnn", 1, vec![16, 16, 3]);
    let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
    let report = pool.run(&Schedule::constant(2, Duration::from_millis(500)));
    assert!(report.total_ok > 0);
    assert_eq!(report.total_errors, 0);
    d.down();
}

#[test]
fn rolling_upgrade_with_pod_kill_serves_continuously() {
    use supersonic::config::{CanaryConfig, VersionSpec};
    use supersonic::metrics::registry::labels;
    use supersonic::telemetry::rollback::VERSION_REQUESTS_COUNTER;

    // Rolling upgrade chaos: icecube_cnn serves v1 (incumbent) with a
    // 30% v2 canary over the full TCP gateway + session-pool stack.
    // Mid-traffic we kill one pod, then promote the canary — the bare
    // name must keep serving throughout: zero errors (a ModelNotFound
    // during the swap would land there) and a served counter that only
    // ever moves forward.
    let mut cfg = base_cfg(ExecutionMode::Simulated);
    cfg.rpc.remote_dispatch = true;
    cfg.rpc.pool_size = 2;
    cfg.server.replicas = 3;
    cfg.server.models[0].versions =
        vec![VersionSpec { version: 1, slowdown: 1.0 }, VersionSpec { version: 2, slowdown: 1.0 }];
    cfg.server.models[0].incumbent = Some(1);
    cfg.server.models[0].canary =
        Some(CanaryConfig { version: 2, weight: 0.3, ..CanaryConfig::default() });
    // Both versions (~152 KB each) fit on every pod: the upgrade is
    // routing-bound, not placement-bound.
    cfg.model_placement.memory_budget_mb = 0.45;
    let d = Deployment::up(cfg).unwrap();
    assert!(d.wait_ready(3, Duration::from_secs(10)));
    std::thread::sleep(Duration::from_millis(300)); // placement reconcile

    let spec = WorkloadSpec::new("icecube_cnn", 2, vec![16, 16, 3]);
    let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
    let worker = std::thread::spawn(move || pool.run(&Schedule::constant(6, Duration::from_millis(1600))));

    let served = |d: &Deployment| {
        ["v1", "v2"]
            .into_iter()
            .map(|v| {
                d.registry
                    .counter(VERSION_REQUESTS_COUNTER, &labels(&[("model", "icecube_cnn"), ("version", v)]))
                    .get()
            })
            .sum::<u64>()
    };
    // Sample the served counter every 25ms while the chaos plays out.
    let mut samples = Vec::new();
    let mut at_kill = 0;
    let mut at_promote = 0;
    let t0 = std::time::Instant::now();
    let mut killed = false;
    let mut promoted = false;
    while t0.elapsed() < Duration::from_millis(1500) {
        samples.push(served(&d));
        if !killed && t0.elapsed() >= Duration::from_millis(400) {
            d.cluster.set_desired(2); // kill one pod mid-traffic
            at_kill = *samples.last().unwrap();
            killed = true;
        }
        if !promoted && t0.elapsed() >= Duration::from_millis(800) {
            assert!(served(&d) > at_kill, "serving stalled after the pod kill");
            let v2_before = d
                .registry
                .counter(VERSION_REQUESTS_COUNTER, &labels(&[("model", "icecube_cnn"), ("version", "v2")]))
                .get();
            assert!(v2_before > 0, "canary arm never served before the promote");
            assert!(d.promote_canary("icecube_cnn"), "promote_canary failed mid-traffic");
            at_promote = *samples.last().unwrap();
            promoted = true;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    let report = worker.join().unwrap();
    samples.push(served(&d));

    assert!(killed && promoted, "chaos schedule never ran");
    assert_eq!(report.total_errors, 0, "errors during the rolling upgrade + pod kill");
    assert!(report.total_ok > 50, "ok={}", report.total_ok);
    // Served counter is monotone non-decreasing across every sample and
    // keeps moving after both chaos events.
    assert!(
        samples.windows(2).all(|w| w[1] >= w[0]),
        "served counter went backwards: {samples:?}"
    );
    assert!(*samples.last().unwrap() > at_promote, "serving stalled after the promote");
    assert_eq!(d.repository.incumbent("icecube_cnn"), Some(2));
    assert!(d.router.as_ref().unwrap().canary_of("icecube_cnn").is_none());
    d.down();
}

#[test]
fn metrics_pipeline_end_to_end() {
    let mut cfg = base_cfg(ExecutionMode::Simulated);
    cfg.monitoring.listen = "127.0.0.1:0".into();
    let d = Deployment::up(cfg).unwrap();
    assert!(d.wait_ready(2, Duration::from_secs(10)));

    let mut client = RpcClient::connect(&d.endpoint()).unwrap();
    for _ in 0..10 {
        assert_eq!(client.infer("icecube_cnn", cnn(2)).unwrap().status, Status::Ok);
    }
    std::thread::sleep(Duration::from_millis(400)); // let the scraper run

    // Prometheus text endpoint includes request counters and utilization.
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(d.metrics_endpoint().unwrap()).unwrap();
    s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
    let mut body = String::new();
    s.read_to_string(&mut body).unwrap();
    assert!(body.contains("inference_requests_total"), "{body}");
    assert!(body.contains("gateway_requests_total"));

    // The store has windowed series the autoscaler queries.
    assert!(d.store.latest("replicas_running").is_some());
    assert!(d
        .store
        .series_ids()
        .iter()
        .any(|id| id.starts_with("request_queue_seconds{") && id.ends_with(":sum")));
    d.down();
}

#[test]
#[cfg_attr(
    not(feature = "pjrt"),
    ignore = "needs compiled PJRT engines: build with --features pjrt after `make artifacts`"
)]
fn multi_model_repository_served_real() {
    let mut cfg = base_cfg(ExecutionMode::Real);
    cfg.server.replicas = 1;
    cfg.server.models = vec![
        ModelConfig { name: "icecube_cnn".into(), ..ModelConfig::default() },
        ModelConfig { name: "cms_transformer".into(), ..ModelConfig::default() },
    ];
    let d = Deployment::up(cfg).unwrap();
    assert!(d.wait_ready(1, Duration::from_secs(15)));
    let mut client = RpcClient::connect(&d.endpoint()).unwrap();
    let r1 = client.infer("icecube_cnn", cnn(2)).unwrap();
    assert_eq!(r1.status, Status::Ok);
    assert_eq!(r1.output.shape(), &[2, 3]);
    let r2 = client
        .infer("cms_transformer", Tensor::zeros(vec![2, 32, 32]))
        .unwrap();
    assert_eq!(r2.status, Status::Ok, "{}", r2.error);
    assert_eq!(r2.output.shape(), &[2, 2]);
    // unknown model still 404s
    assert_eq!(client.infer("nope", cnn(1)).unwrap().status, Status::ModelNotFound);
    d.down();
}
