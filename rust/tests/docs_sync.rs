//! Doc-sync gates — documentation that must track the code.
//!
//! `docs/CONFIG.md` is covered by `config_doc_covers_every_schema_field`
//! (a unit test next to the schema key catalogs); this file holds the
//! repository-level gates: the operations runbook must document every
//! bench binary, so new benches cannot land undocumented.

use std::fs;
use std::path::{Path, PathBuf};

fn read_doc(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../docs").join(name);
    fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{} must exist and be readable: {e}", path.display()))
}

fn bench_stems() -> Vec<String> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("benches");
    let mut stems: Vec<String> = fs::read_dir(&dir)
        .expect("rust/benches/ must exist")
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("rs"))
        .map(|p: PathBuf| p.file_stem().unwrap().to_str().unwrap().to_string())
        .collect();
    stems.sort();
    stems
}

#[test]
fn operations_doc_mentions_every_bench() {
    let doc = read_doc("OPERATIONS.md");
    let stems = bench_stems();
    assert!(
        stems.len() >= 10,
        "expected the full bench set, found only {stems:?}"
    );
    for stem in &stems {
        assert!(
            doc.contains(stem),
            "docs/OPERATIONS.md does not mention bench '{stem}' \
             (rust/benches/{stem}.rs); document how to run and read it, \
             or the bench set and the runbook drift apart"
        );
    }
}

#[test]
fn every_bench_is_registered_in_cargo_and_make() {
    // A bench that exists on disk but is missing from Cargo.toml (no
    // `[[bench]]` entry => never compiled) or from the `make bench` loop
    // (never run) is a silent hole in the evaluation.
    let manifest =
        fs::read_to_string(Path::new(env!("CARGO_MANIFEST_DIR")).join("Cargo.toml")).unwrap();
    let makefile =
        fs::read_to_string(Path::new(env!("CARGO_MANIFEST_DIR")).join("../Makefile")).unwrap();
    for stem in bench_stems() {
        assert!(
            manifest.contains(&format!("name = \"{stem}\"")),
            "rust/benches/{stem}.rs has no [[bench]] entry in Cargo.toml"
        );
        assert!(
            makefile.contains(&stem),
            "rust/benches/{stem}.rs is not in the Makefile `bench` target loop"
        );
    }
}

#[test]
fn every_bench_is_smoke_registered() {
    // `make bench-smoke` is a CI gate: it runs every bench in short
    // deterministic mode. The Makefile drives both `bench` and
    // `bench-smoke` from one `BENCHES :=` list, so this gate checks that
    // every bench binary on disk appears in that list — a bench missing
    // from it would compile forever without its runtime path ever being
    // exercised.
    let makefile =
        fs::read_to_string(Path::new(env!("CARGO_MANIFEST_DIR")).join("../Makefile")).unwrap();
    let benches_list: String = makefile
        .lines()
        .skip_while(|l| !l.starts_with("BENCHES :="))
        .take_while(|l| l.starts_with("BENCHES :=") || l.starts_with('\t'))
        .collect::<Vec<_>>()
        .join(" ");
    assert!(
        !benches_list.is_empty(),
        "Makefile must define the BENCHES := list driving bench/bench-smoke"
    );
    assert!(
        makefile.contains("bench-smoke:") && makefile.contains("SUPERSONIC_SMOKE=1"),
        "Makefile must keep the bench-smoke target running with SUPERSONIC_SMOKE=1"
    );
    for stem in bench_stems() {
        assert!(
            benches_list.split_whitespace().any(|w| w == stem),
            "rust/benches/{stem}.rs is not in the Makefile BENCHES list — \
             it will never run under `make bench-smoke` (the CI gate)"
        );
    }
}

#[test]
fn config_doc_documents_every_priority_lane() {
    // The priority classes are schema surface (values of
    // `server.priorities.*`): a lane added to the enum without a
    // CONFIG.md entry must fail `make docs-check`, exactly like an
    // undocumented schema key.
    let doc = read_doc("CONFIG.md");
    for p in supersonic::rpc::codec::Priority::ALL {
        assert!(
            doc.contains(&format!("`{}`", p.name())),
            "docs/CONFIG.md does not document priority lane '{}'; the \
             server.priorities section must name every class",
            p.name()
        );
    }
}

#[test]
fn architecture_doc_names_every_backend_impl() {
    // Every `Backend` implementation (by Rust type name) and every wire
    // backend name must appear in the architecture doc's backend-layer
    // section: a new runtime cannot land undocumented.
    let doc = read_doc("ARCHITECTURE.md");
    for name in supersonic::engine::BACKEND_IMPLS {
        assert!(
            doc.contains(name),
            "docs/ARCHITECTURE.md does not mention backend implementation '{name}'; \
             document it in the backend-layer section"
        );
    }
    for name in supersonic::config::schema::BACKEND_NAMES {
        assert!(
            doc.contains(&format!("`{name}`")),
            "docs/ARCHITECTURE.md does not name the `{name}` backend"
        );
    }
}

#[test]
fn operations_doc_mentions_make_targets() {
    // The runbook must stay anchored to the real build entry points.
    let doc = read_doc("OPERATIONS.md");
    for target in ["make artifacts", "make bench", "make docs-check", "make test"] {
        assert!(doc.contains(target), "docs/OPERATIONS.md must mention `{target}`");
    }
}

#[test]
fn operations_doc_documents_every_trace_stage() {
    // The stage labels on `request_stage_seconds{stage=...}` are the
    // vocabulary of the latency-breakdown runbook: a stage added to the
    // tracer without a runbook entry must fail `make docs-check`.
    let doc = read_doc("OPERATIONS.md");
    for stage in supersonic::telemetry::STAGES {
        assert!(
            doc.contains(&format!("`{stage}`")),
            "docs/OPERATIONS.md does not document trace stage '{stage}' \
             (a request_stage_seconds label); explain it in the tracing \
             runbook section"
        );
    }
}

#[test]
fn operations_doc_documents_every_version_metric() {
    // The model-version lifecycle exports its own metric family
    // (per-version traffic, replica gauges, the rollback counter): every
    // name must appear in the canary runbook, or a dashboard built from
    // the docs silently misses the rollout signals.
    let doc = read_doc("OPERATIONS.md");
    for metric in supersonic::telemetry::rollback::VERSION_METRICS {
        assert!(
            doc.contains(&format!("`{metric}`")),
            "docs/OPERATIONS.md does not document version metric '{metric}'; \
             the canary_rollout runbook must cover every version-lifecycle \
             series"
        );
    }
}

#[test]
fn operations_doc_documents_rollback_alert() {
    // The auto-rollback alert is a page: it needs a runbook entry with
    // rollback troubleshooting, same contract as the SLO alerts.
    let doc = read_doc("OPERATIONS.md");
    let alert = supersonic::telemetry::rollback::ROLLBACK_ALERT;
    assert!(
        doc.contains(&format!("`{alert}`")),
        "docs/OPERATIONS.md does not document the '{alert}' alert; the \
         canary_rollout runbook must explain why it fires and how to recover"
    );
}

#[test]
fn operations_doc_documents_every_federation_metric() {
    // The federation tier exports its own metric family (per-site
    // traffic, spillover, WAN hops, budget): every name must appear in
    // the federation runbook, or the site-outage troubleshooting guide
    // points at series nobody documented.
    let doc = read_doc("OPERATIONS.md");
    for metric in supersonic::federation::FEDERATION_METRICS {
        assert!(
            doc.contains(&format!("`{metric}`")),
            "docs/OPERATIONS.md does not document federation metric '{metric}'; \
             the federation_ablation runbook must cover every federation series"
        );
    }
}

#[test]
fn operations_doc_documents_site_outage_alert() {
    // A whole-site outage is a page: it needs a runbook entry with
    // spillover/repatriation troubleshooting, same contract as the SLO
    // and rollback alerts.
    let doc = read_doc("OPERATIONS.md");
    let alert = supersonic::federation::SITE_OUTAGE_ALERT;
    assert!(
        doc.contains(&format!("`{alert}`")),
        "docs/OPERATIONS.md does not document the '{alert}' alert; the \
         federation runbook must explain why it fires and how traffic \
         fails over and repatriates"
    );
}

#[test]
fn operations_doc_documents_cpu_scaler_metrics() {
    // The class-partitioned CPU scaler's trigger/target gauges must be
    // documented next to the autoscaling runbook.
    let doc = read_doc("OPERATIONS.md");
    for metric in ["autoscaler_cpu_demand", "autoscaler_cpu_desired", "canary_ramp_weight"] {
        assert!(
            doc.contains(&format!("`{metric}`")),
            "docs/OPERATIONS.md does not document metric '{metric}'"
        );
    }
}

#[test]
fn operations_doc_documents_every_decision_kind() {
    // The flight recorder's decision kinds are the vocabulary of the
    // `supersonic explain` runbook: a kind added to the recorder without
    // a runbook entry must fail `make docs-check`.
    let doc = read_doc("OPERATIONS.md");
    for kind in supersonic::telemetry::flight::DECISION_KINDS {
        assert!(
            doc.contains(&format!("`{kind}`")),
            "docs/OPERATIONS.md does not document decision kind '{kind}' \
             (a control_decisions_total label and explain-output string); \
             cover it in the control-plane explain runbook"
        );
    }
}

#[test]
fn operations_doc_documents_every_control_loop() {
    // Every control loop the recorder and the loop-health series label
    // by name must appear in the runbook — the staleness-troubleshooting
    // entry points operators at these labels.
    let doc = read_doc("OPERATIONS.md");
    for l in supersonic::telemetry::flight::LOOP_LABELS {
        assert!(
            doc.contains(&format!("`{l}`")),
            "docs/OPERATIONS.md does not document control loop '{l}' \
             (a control_loop_* / control_decisions_total label); name it \
             in the loop-health runbook section"
        );
    }
    for metric in [
        "control_decisions_total",
        "control_loop_tick_seconds",
        "control_loop_last_run_seconds",
    ] {
        assert!(
            doc.contains(&format!("`{metric}`")),
            "docs/OPERATIONS.md does not document metric '{metric}'"
        );
    }
}

#[test]
fn operations_doc_documents_every_slo_alert() {
    // Every alert name the burn-rate engine can fire must have a runbook
    // entry — an undocumented page is an unactionable page.
    let doc = read_doc("OPERATIONS.md");
    for alert in supersonic::telemetry::slo::SLO_ALERTS {
        assert!(
            doc.contains(&format!("`{alert}`")),
            "docs/OPERATIONS.md does not document SLO alert '{alert}'; \
             the burn-rate runbook must cover every alert the engine fires"
        );
    }
}
