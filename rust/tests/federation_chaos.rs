//! Federation chaos test: kill a whole site under live traffic, assert
//! the federation keeps serving (spillover to the surviving sites),
//! raises the `site_outage` alert, and repatriates traffic to the home
//! site after it recovers.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use supersonic::config::{
    AutoscalerConfig, ClusterConfig, DeploymentConfig, ExecutionMode, FederationConfig,
    GatewayConfig, ModelConfig, ModelPlacementConfig, MonitoringConfig,
    PerModelScalingConfig, ServerConfig, ServiceModelConfig, SiteConfig,
};
use supersonic::deployment::Deployment;
use supersonic::federation::SITE_OUTAGE_ALERT;
use supersonic::metrics::exposition::render;
use supersonic::rpc::client::RpcClient;
use supersonic::rpc::codec::Status;
use supersonic::runtime::Tensor;

const HOME: &str = "purdue";

fn site(name: &str, wan: &[(&str, f64)]) -> SiteConfig {
    SiteConfig {
        name: name.into(),
        pod_budget: 4,
        replicas: 2,
        nodes: 2,
        gpus_per_node: 2,
        cpu_replicas: 0,
        wan: wan
            .iter()
            .map(|(peer, secs)| (peer.to_string(), Duration::from_secs_f64(*secs)))
            .collect::<BTreeMap<_, _>>(),
    }
}

fn fed_cfg() -> DeploymentConfig {
    DeploymentConfig {
        name: "fedtest".into(),
        server: ServerConfig {
            replicas: 2,
            models: vec![ModelConfig {
                name: "icecube_cnn".into(),
                max_queue_delay: Duration::from_millis(1),
                preferred_batch: 8,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(2),
                    per_row: Duration::from_micros(100),
                },
                ..ModelConfig::default()
            }],
            repository: "artifacts".into(),
            startup_delay: Duration::from_millis(10),
            execution: ExecutionMode::Simulated,
            queue_capacity: 256,
            util_window: 5.0,
            batch_mode: Default::default(),
            priorities: Default::default(),
        },
        gateway: GatewayConfig::default(),
        autoscaler: AutoscalerConfig {
            enabled: true,
            min_replicas: 1,
            max_replicas: 6,
            poll_interval: Duration::from_millis(100),
            per_model: PerModelScalingConfig {
                enabled: true,
                // High threshold: this test exercises outage/repatriation,
                // not scale-ups — keep the pod counts stable.
                threshold: 10_000.0,
                min_replicas: 1,
                max_replicas: 4,
            },
            ..AutoscalerConfig::default()
        },
        cluster: ClusterConfig {
            nodes: 3,
            gpus_per_node: 2,
            pod_start_delay: Duration::from_millis(20),
            termination_grace: Duration::from_millis(20),
            pod_failure_rate: 0.0,
        },
        federation: FederationConfig {
            sites: vec![
                site(HOME, &[("nrp", 0.002), ("uchicago", 0.004)]),
                site("nrp", &[]),
                site("uchicago", &[]),
            ],
            gateway_site: HOME.into(),
            rebalance_interval: Duration::from_millis(200),
            spillover_queue_depth: 8.0,
        },
        monitoring: MonitoringConfig {
            listen: String::new(),
            scrape_interval: Duration::from_millis(100),
            retention: Duration::from_secs(600),
            tracing: false,
        },
        model_placement: ModelPlacementConfig {
            memory_budget_mb: 4096.0,
            ..ModelPlacementConfig::default()
        },
        engines: Default::default(),
        observability: Default::default(),
        rpc: Default::default(),
        time_scale: 4.0,
    }
}

/// Poll `probe` every 10ms until it returns true or `timeout` elapses.
fn wait_for(timeout: Duration, probe: impl Fn() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if probe() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    probe()
}

#[test]
fn site_outage_keeps_serving_and_repatriates() {
    let d = Deployment::up(fed_cfg()).unwrap();
    let fed = Arc::clone(d.federation.as_ref().expect("federated deployment"));
    // 3 sites x 2 replicas.
    assert!(d.wait_ready(6, Duration::from_secs(10)), "federation never became ready");

    // Continuous traffic from a background client for the whole run.
    let stop = Arc::new(AtomicBool::new(false));
    let ok = Arc::new(AtomicUsize::new(0));
    let failed = Arc::new(AtomicUsize::new(0));
    let driver = {
        let addr = d.endpoint();
        let (stop, ok, failed) = (Arc::clone(&stop), Arc::clone(&ok), Arc::clone(&failed));
        std::thread::spawn(move || {
            let mut client = RpcClient::connect(&addr).unwrap();
            while !stop.load(Ordering::SeqCst) {
                match client.infer("icecube_cnn", Tensor::zeros(vec![1, 16, 16, 3])) {
                    Ok(resp) if resp.status == Status::Ok => {
                        ok.fetch_add(1, Ordering::SeqCst);
                    }
                    _ => {
                        failed.fetch_add(1, Ordering::SeqCst);
                        // The gateway stream is dead after an I/O error;
                        // reconnect and keep driving.
                        if let Ok(c) = RpcClient::connect(&addr) {
                            client = c;
                        }
                    }
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        })
    };

    // Phase 1: healthy federation — the home (gateway) site is cheapest
    // and must carry traffic.
    assert!(
        wait_for(Duration::from_secs(5), || fed.router.site_requests(HOME) > 10),
        "home site never served while healthy: {:?}",
        fed.running_by_site()
    );

    // Phase 2: kill the whole home site mid-traffic.
    assert!(fed.fail_site(HOME));
    assert!(
        wait_for(Duration::from_secs(10), || {
            fed.running_by_site().get(HOME) == Some(&0)
        }),
        "home site pods never drained: {:?}",
        fed.running_by_site()
    );
    let ok_at_outage = ok.load(Ordering::SeqCst);
    let home_at_outage = fed.router.site_requests(HOME);
    let remote_at_outage: u64 =
        fed.router.site_requests("nrp") + fed.router.site_requests("uchicago");

    // Service must continue on the surviving sites...
    assert!(
        wait_for(Duration::from_secs(5), || {
            ok.load(Ordering::SeqCst) > ok_at_outage + 20
        }),
        "traffic stalled during the site outage"
    );
    // ...routed to the remote sites, not the dead one.
    let remote_now: u64 =
        fed.router.site_requests("nrp") + fed.router.site_requests("uchicago");
    assert!(remote_now > remote_at_outage, "remote sites took no spillover traffic");
    assert_eq!(
        fed.router.site_requests(HOME),
        home_at_outage,
        "requests were routed to a site with zero warm capacity"
    );
    // The rebalancer flags the outage.
    assert!(
        wait_for(Duration::from_secs(5), || {
            render(&d.registry).contains(&format!(
                "slo_alert_active{{alert=\"{SITE_OUTAGE_ALERT}\",site=\"{HOME}\"}} 1"
            ))
        }),
        "site_outage alert never fired for the dead site"
    );

    // Phase 3: recover the site; traffic must repatriate to the cheapest
    // (home) site once its capacity is warm again.
    assert!(fed.recover_site(HOME));
    assert!(
        wait_for(Duration::from_secs(10), || {
            fed.running_by_site().get(HOME).copied().unwrap_or(0) > 0
        }),
        "home site never came back: {:?}",
        fed.running_by_site()
    );
    let home_at_recovery = fed.router.site_requests(HOME);
    assert!(
        wait_for(Duration::from_secs(10), || {
            fed.router.site_requests(HOME) > home_at_recovery + 10
        }),
        "traffic never repatriated to the recovered home site"
    );
    // The alert resolves once the site is back.
    assert!(
        wait_for(Duration::from_secs(5), || {
            render(&d.registry).contains(&format!(
                "slo_alert_active{{alert=\"{SITE_OUTAGE_ALERT}\",site=\"{HOME}\"}} 0"
            ))
        }),
        "site_outage alert never resolved after recovery"
    );

    stop.store(true, Ordering::SeqCst);
    driver.join().unwrap();
    let (ok, failed) = (ok.load(Ordering::SeqCst), failed.load(Ordering::SeqCst));
    // Continuous service: the overwhelming majority of requests succeed
    // through the outage (a handful may race the pod drain).
    assert!(ok > 100, "too little traffic flowed: ok={ok}");
    assert!(
        failed * 20 <= ok,
        "more than 5% of requests failed across the outage: ok={ok} failed={failed}"
    );
    d.down();
}

#[test]
fn federated_routing_spills_over_and_prices_wan_hops() {
    // Structural smoke on the routing tier itself: with the home site
    // drained, every pick is a WAN hop to a remote site.
    let d = Deployment::up(fed_cfg()).unwrap();
    let fed = Arc::clone(d.federation.as_ref().expect("federated deployment"));
    assert!(d.wait_ready(6, Duration::from_secs(10)));
    assert!(fed.fail_site(HOME));
    assert!(wait_for(Duration::from_secs(10), || {
        fed.running_by_site().get(HOME) == Some(&0)
    }));

    let mut client = RpcClient::connect(&d.endpoint()).unwrap();
    for _ in 0..10 {
        let resp = client.infer("icecube_cnn", Tensor::zeros(vec![1, 16, 16, 3])).unwrap();
        assert_eq!(resp.status, Status::Ok, "{}", resp.error);
    }
    assert_eq!(fed.router.site_requests(HOME), 0, "dead site must take no traffic");
    assert!(
        fed.router.site_requests("nrp") + fed.router.site_requests("uchicago") >= 10,
        "remote sites must carry the load"
    );
    d.down();
}
