//! Golden snapshot of the `/metrics` exposition for the observability
//! series: the stage-breakdown histograms, the trace health counters and
//! the SLO alert gauges. Deterministic (exact binary-fraction durations,
//! simulated clock), so the rendered text is byte-stable: a change to
//! series names, labels, or value formatting must update this golden on
//! purpose.

use std::time::Duration;

use supersonic::config::{ObservabilityConfig, SloConfig};
use supersonic::metrics::exposition::render;
use supersonic::metrics::registry::Registry;
use supersonic::metrics::store::MetricStore;
use supersonic::telemetry::slo::SloEngine;
use supersonic::telemetry::{Span, StageRecorder, Tracer, ROOT_SPAN};
use supersonic::util::clock::Clock;

fn span(trace_id: u64, name: &str, start: f64, end: f64) -> Span {
    Span { trace_id, name: name.into(), start, end }
}

/// Everything except bucket expansion (19 lines per stage, elided to keep
/// the golden readable; bucket invariants are property-tested in
/// `property_invariants.rs`).
const GOLDEN: &str = "\
# TYPE canary_ramp_weight gauge
canary_ramp_weight{model=\"icecube_cnn\"} 0.1
# TYPE control_decisions_total counter
control_decisions_total{kind=\"budget_shift\",loop=\"rebalancer\"} 1
control_decisions_total{kind=\"spillover\",loop=\"federation_router\"} 1
# TYPE control_loop_last_run_seconds gauge
control_loop_last_run_seconds{loop=\"rebalancer\"} 0.25
# TYPE control_loop_tick_seconds histogram
control_loop_tick_seconds_sum{loop=\"rebalancer\"} 0.25
control_loop_tick_seconds_count{loop=\"rebalancer\"} 1
# TYPE federation_site_budget gauge
federation_site_budget{site=\"nrp\"} 3
federation_site_budget{site=\"purdue\"} 5
# TYPE federation_site_requests_total counter
federation_site_requests_total{site=\"nrp\"} 5
federation_site_requests_total{site=\"purdue\"} 9
# TYPE federation_spillover_total counter
federation_spillover_total{site=\"nrp\"} 2
federation_spillover_total{site=\"purdue\"} 0
# TYPE federation_wan_hops_total counter
federation_wan_hops_total{site=\"nrp\"} 2
federation_wan_hops_total{site=\"purdue\"} 0
# TYPE gateway_model_version_latency_seconds histogram
gateway_model_version_latency_seconds_sum{model=\"icecube_cnn\",version=\"v1\"} 0.375
gateway_model_version_latency_seconds_count{model=\"icecube_cnn\",version=\"v1\"} 2
gateway_model_version_latency_seconds_sum{model=\"icecube_cnn\",version=\"v2\"} 0.375
gateway_model_version_latency_seconds_count{model=\"icecube_cnn\",version=\"v2\"} 2
# TYPE model_version_errors_total counter
model_version_errors_total{model=\"icecube_cnn\",version=\"v2\"} 1
# TYPE model_version_replicas gauge
model_version_replicas{model=\"icecube_cnn\",version=\"v1\"} 1
model_version_replicas{model=\"icecube_cnn\",version=\"v2\"} 1
# TYPE model_version_requests_total counter
model_version_requests_total{model=\"icecube_cnn\",version=\"v1\"} 6
model_version_requests_total{model=\"icecube_cnn\",version=\"v2\"} 2
# TYPE model_version_rollback_total counter
model_version_rollback_total{model=\"icecube_cnn\"} 1
# TYPE request_stage_seconds histogram
request_stage_seconds_sum{stage=\"admit\"} 0.125
request_stage_seconds_count{stage=\"admit\"} 2
request_stage_seconds_sum{stage=\"batch\"} 0.0625
request_stage_seconds_count{stage=\"batch\"} 2
request_stage_seconds_sum{stage=\"compute\"} 0.375
request_stage_seconds_count{stage=\"compute\"} 2
request_stage_seconds_sum{stage=\"other\"} 0.3125
request_stage_seconds_count{stage=\"other\"} 2
request_stage_seconds_sum{stage=\"queue\"} 0.25
request_stage_seconds_count{stage=\"queue\"} 2
request_stage_seconds_sum{stage=\"ratelimit\"} 0.125
request_stage_seconds_count{stage=\"ratelimit\"} 2
request_stage_seconds_sum{stage=\"retry\"} 0.125
request_stage_seconds_count{stage=\"retry\"} 2
request_stage_seconds_sum{stage=\"route\"} 0.125
request_stage_seconds_count{stage=\"route\"} 2
# TYPE request_total_seconds histogram
request_total_seconds_sum 1.5
request_total_seconds_count 2
# TYPE slo_alert_active gauge
slo_alert_active{alert=\"error_budget_burn_rate\",model=\"particlenet\"} 0
slo_alert_active{alert=\"latency_burn_rate\",model=\"particlenet\"} 0
slo_alert_active{alert=\"site_outage\",site=\"nrp\"} 1
slo_alert_active{alert=\"site_outage\",site=\"purdue\"} 0
# TYPE trace_partial_total counter
trace_partial_total{site=\"local\"} 1
# TYPE trace_spans_dropped_total counter
trace_spans_dropped_total{site=\"local\"} 2";

#[test]
fn observability_series_exposition_matches_golden() {
    let registry = Registry::new();
    let recorder = StageRecorder::new(&registry);

    // Two complete traces with exact-binary-fraction stage layouts.
    let tracer = Tracer::new(Clock::simulated(), 1024, true);
    tracer.record(span(1, ROOT_SPAN, 0.0, 1.0));
    tracer.record(span(1, "admit", 0.0, 0.125));
    tracer.record(span(1, "ratelimit", 0.125, 0.25));
    tracer.record(span(1, "route", 0.25, 0.375));
    tracer.record(span(1, "retry", 0.375, 0.5));
    tracer.record(span(1, "queue", 0.5, 0.75));
    tracer.record(span(1, "batch", 0.75, 0.8125));
    tracer.record(span(1, "compute", 0.8125, 0.9375)); // other = 0.0625
    tracer.record(span(2, ROOT_SPAN, 0.0, 0.5));
    tracer.record(span(2, "compute", 0.25, 0.5)); // other = 0.25
    recorder.observe(&tracer.trace(1));
    recorder.observe(&tracer.trace(2));

    // A tracer that overflows: two spans dropped, the surviving trace is
    // partial and is counted instead of folded into the breakdown.
    let small = Tracer::new(Clock::simulated(), 1, true);
    small.bind_registry(&registry);
    small.record(span(9, ROOT_SPAN, 0.0, 1.0));
    small.record(span(9, "queue", 0.0, 0.5));
    small.record(span(9, "compute", 0.5, 1.0));
    recorder.observe(&small.trace(9));

    // The version-lifecycle series a live canary split exports: gateway
    // per-(model, version) traffic, placement's replica gauges, and one
    // fired auto-rollback.
    use supersonic::metrics::registry::labels;
    use supersonic::telemetry::rollback::{
        ROLLBACK_COUNTER, VERSION_ERRORS_COUNTER, VERSION_LATENCY_HIST, VERSION_REPLICAS_GAUGE,
        VERSION_REQUESTS_COUNTER,
    };
    for (ver, n) in [("v1", 6u64), ("v2", 2)] {
        let l = labels(&[("model", "icecube_cnn"), ("version", ver)]);
        registry.counter(VERSION_REQUESTS_COUNTER, &l).add(n);
        registry.histogram(VERSION_LATENCY_HIST, &l).observe(0.125);
        registry.histogram(VERSION_LATENCY_HIST, &l).observe(0.25);
        registry.gauge(VERSION_REPLICAS_GAUGE, &l).set(1.0);
    }
    registry
        .counter(VERSION_ERRORS_COUNTER, &labels(&[("model", "icecube_cnn"), ("version", "v2")]))
        .add(1);
    registry.counter(ROLLBACK_COUNTER, &labels(&[("model", "icecube_cnn")])).inc();

    // Federation-tier series: a ramping canary's current weight, the
    // per-site routed/spillover/WAN counters, the rebalancer's budget
    // gauges, and a whole-site outage alert (fired for one site,
    // resolved for the other).
    {
        use supersonic::federation::SITE_OUTAGE_ALERT;
        use supersonic::telemetry::slo::ALERT_GAUGE;
        registry
            .gauge("canary_ramp_weight", &labels(&[("model", "icecube_cnn")]))
            .set(0.1);
        for (site, requests, spill, wan, budget, outage) in
            [("nrp", 5u64, 2u64, 2u64, 3.0, 1.0), ("purdue", 9, 0, 0, 5.0, 0.0)]
        {
            let l = labels(&[("site", site)]);
            registry.counter("federation_site_requests_total", &l).add(requests);
            registry.counter("federation_spillover_total", &l).add(spill);
            registry.counter("federation_wan_hops_total", &l).add(wan);
            registry.gauge("federation_site_budget", &l).set(budget);
            registry
                .gauge(ALERT_GAUGE, &labels(&[("alert", SITE_OUTAGE_ALERT), ("site", site)]))
                .set(outage);
        }
    }

    // Control-plane observability: two flight-recorder decisions (the
    // per-(loop, kind) counter) and one instrumented loop tick whose
    // body takes exactly 0.25 simulated seconds (the tick histogram and
    // the last-run staleness gauge).
    {
        use supersonic::telemetry::flight::{DecisionEvent, FlightRecorder, LoopTicker};
        let fclock = Clock::simulated();
        let flight = FlightRecorder::new(fclock.clone(), 16, 600.0, registry.clone());
        flight.record(DecisionEvent::new("rebalancer", "budget_shift").site("nrp"));
        flight.record(
            DecisionEvent::new("federation_router", "spillover")
                .site("purdue")
                .model("icecube_cnn"),
        );
        let ticker = LoopTicker::new(&registry, fclock.clone(), "rebalancer");
        ticker.tick(|| fclock.advance(Duration::from_millis(250)));
    }

    // The SLO engine pre-registers its alert gauges at 0 (resolved).
    let cfg = ObservabilityConfig {
        slos: vec![SloConfig {
            model: "particlenet".into(),
            latency_p99: Duration::from_millis(100),
            error_budget: 0.01,
        }],
        ..ObservabilityConfig::default()
    };
    let _engine = SloEngine::new(
        cfg,
        registry.clone(),
        MetricStore::new(Duration::from_secs(3600)),
        Clock::simulated(),
    );

    let text = render(&registry);
    let filtered: Vec<&str> = text.lines().filter(|l| !l.contains("_bucket")).collect();
    assert_eq!(
        filtered.join("\n"),
        GOLDEN,
        "observability exposition drifted from the golden snapshot:\n{text}"
    );

    // Spot-check the elided bucket expansion: cumulative close at +Inf.
    assert!(text.contains("request_stage_seconds_bucket{stage=\"compute\",le=\"+Inf\"} 2"));
    assert!(text.contains("request_total_seconds_bucket{le=\"+Inf\"} 2"));
}
