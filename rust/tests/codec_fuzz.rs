//! Codec fuzz/property suite — the wire decoders must be total.
//!
//! `decode_request`, `decode_response` and `read_frame` sit directly on
//! the network: every byte they consume is attacker-controlled, and a
//! panic in any of them kills a server connection thread (or, in the
//! demultiplexed path, a whole multiplexed session carrying dozens of
//! in-flight requests). This suite drives them with adversarial input —
//! exhaustive truncations, seeded random mutations, corrupted length
//! prefixes, type-confused payloads — asserting they always return
//! `Err`/`None` or a valid value, never panic. A randomized round-trip
//! property over tensors × priorities × trace ids × tokens pins the
//! decoders to the encoders (both the buffered and the streaming
//! zero-copy path).
//!
//! Deterministic: all randomness flows from fixed `Rng::seeded` seeds.

use supersonic::rpc::codec::{
    decode_request, decode_response, encode_request, encode_response, read_frame, write_frame,
    write_request_frame, write_response_frame, InferRequest, InferResponse, Priority, Status,
};
use supersonic::runtime::Tensor;
use supersonic::util::rng::Rng;

const ALL_STATUSES: [Status; 7] = [
    Status::Ok,
    Status::Unauthorized,
    Status::RateLimited,
    Status::Overloaded,
    Status::BadRequest,
    Status::Internal,
    Status::ModelNotFound,
];

fn sample_tensor() -> Tensor {
    Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap()
}

/// A small corpus of valid encodings covering both message types and
/// their branches (infer/health, ok/error, priorities, tracing).
fn corpus() -> Vec<Vec<u8>> {
    let mut traced = InferRequest::infer(42, "particlenet", sample_tensor());
    traced.token = "secret-token".into();
    traced.trace_id = 0xABCD_EF01_2345_6789;
    traced.priority = Some(Priority::Critical);
    let mut untraced = InferRequest::infer(7, "icecube_cnn", Tensor::zeros(vec![1, 4]));
    untraced.sampled = false;
    untraced.priority = Some(Priority::Bulk);
    let mut ok = InferResponse::ok(9, sample_tensor());
    ok.queue_us = 1500;
    ok.compute_us = 3200;
    ok.batch_size = 8;
    vec![
        encode_request(&traced),
        encode_request(&untraced),
        encode_request(&InferRequest::health(3)),
        encode_response(&ok),
        encode_response(&InferResponse::err(5, Status::Overloaded, "queue full")),
    ]
}

/// Neither decoder may panic; whatever they return is discarded. The
/// same bytes go through both decoders deliberately (type confusion: a
/// response fed to the request decoder and vice versa).
fn decode_both(buf: &[u8]) {
    let _ = decode_request(buf);
    let _ = decode_response(buf);
}

#[test]
fn exhaustive_truncations_return_err() {
    // Every strict prefix of a valid encoding must decode to Err — a
    // partial message can never be mistaken for a complete one (and the
    // decoder must not panic reaching past the end).
    for buf in corpus() {
        // A prefix of one message type decoding as the OTHER type would
        // be possible and fine — so the no-panic sweep runs both
        // decoders, and the strict must-be-Err property is then checked
        // per decoder against its own message type below.
        for cut in 0..buf.len() {
            decode_both(&buf[..cut]);
        }
        if decode_request(&buf).is_ok() {
            for cut in 0..buf.len() {
                assert!(
                    decode_request(&buf[..cut]).is_err(),
                    "request prefix {cut}/{} decoded as complete",
                    buf.len()
                );
            }
        }
        if decode_response(&buf).is_ok() {
            for cut in 0..buf.len() {
                assert!(
                    decode_response(&buf[..cut]).is_err(),
                    "response prefix {cut}/{} decoded as complete",
                    buf.len()
                );
            }
        }
    }
}

#[test]
fn exhaustive_single_byte_mutations_never_panic() {
    // Flip every byte of every corpus message through a handful of
    // adversarial values; decoding may fail or (rarely) succeed with
    // different content, but must never panic.
    for buf in corpus() {
        for i in 0..buf.len() {
            for val in [0x00, 0x01, 0x7F, 0x80, 0xFE, 0xFF] {
                let mut m = buf.clone();
                m[i] = val;
                decode_both(&m);
            }
        }
    }
}

#[test]
fn seeded_random_mutations_never_panic() {
    let mut rng = Rng::seeded(0xC0DE_C0DE);
    let corpus = corpus();
    for _ in 0..4000 {
        let mut buf = rng.pick(&corpus).clone();
        // 1..=8 random byte mutations, plus occasional truncation or
        // random-tail extension, so structural fields (lengths, counts)
        // get corrupted together with payload bytes.
        for _ in 0..rng.range_u64(1, 8) {
            let i = rng.below(buf.len());
            buf[i] = rng.next_u64() as u8;
        }
        if rng.chance(0.25) {
            buf.truncate(rng.below(buf.len() + 1));
        } else if rng.chance(0.25) {
            for _ in 0..rng.below(16) {
                buf.push(rng.next_u64() as u8);
            }
        }
        decode_both(&buf);
    }
}

#[test]
fn random_garbage_never_panics() {
    let mut rng = Rng::seeded(0xBAD_F00D);
    for _ in 0..4000 {
        let len = rng.below(512);
        let buf: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        decode_both(&buf);
        // Garbage through the framing layer too: read_frame either
        // errors, reports EOF, or returns a frame that then fails to
        // decode — never panics.
        let mut r = &buf[..];
        if let Ok(Some(frame)) = read_frame(&mut r) {
            decode_both(&frame);
        }
    }
}

#[test]
fn length_prefix_corruption_is_rejected() {
    let payload = encode_request(&InferRequest::infer(1, "m", sample_tensor()));
    let mut framed = Vec::new();
    write_frame(&mut framed, &payload).unwrap();

    // Oversized length prefixes (beyond MAX_FRAME) must error.
    for huge in [u32::MAX, (64 << 20) + 1] {
        let mut m = framed.clone();
        m[..4].copy_from_slice(&huge.to_le_bytes());
        assert!(read_frame(&mut &m[..]).is_err(), "len {huge} accepted");
    }
    // A length prefix pointing past the available bytes must error, not
    // hang or panic.
    let mut m = framed.clone();
    m[..4].copy_from_slice(&((payload.len() + 1) as u32).to_le_bytes());
    assert!(read_frame(&mut &m[..]).is_err());
    // A shortened prefix yields a frame that then fails to decode.
    let mut m = framed.clone();
    m[..4].copy_from_slice(&((payload.len() - 1) as u32).to_le_bytes());
    let frame = read_frame(&mut &m[..]).unwrap().unwrap();
    assert!(decode_request(&frame).is_err());
    // Partial headers at EOF (0..4 bytes) must not panic.
    for cut in 0..4 {
        let _ = read_frame(&mut &framed[..cut]);
    }
}

#[test]
fn hostile_tensor_dims_are_rejected() {
    // A response claiming a 0xFFFF_FFFF x 0xFFFF_FFFF tensor with a tiny
    // byte payload: the element-count product overflows usize on 32-bit
    // and far exceeds the byte length everywhere — must be Err.
    let mut buf = Vec::new();
    buf.push(Status::Ok as u8);
    buf.extend_from_slice(&1u64.to_le_bytes()); // request_id
    buf.extend_from_slice(&0u32.to_le_bytes()); // queue_us
    buf.extend_from_slice(&0u32.to_le_bytes()); // compute_us
    buf.extend_from_slice(&1u32.to_le_bytes()); // batch_size
    buf.push(2); // ndim
    buf.extend_from_slice(&u32::MAX.to_le_bytes());
    buf.extend_from_slice(&u32::MAX.to_le_bytes());
    buf.extend_from_slice(&16u32.to_le_bytes()); // claimed byte length
    buf.extend_from_slice(&[0u8; 16]);
    assert!(decode_response(&buf).is_err());

    // Same shape attack through the request path.
    let mut req = encode_request(&InferRequest::infer(1, "m", Tensor::zeros(vec![2, 2])));
    // tensor body starts after kind(1)+id(8)+trace(8)+flags(1)+token(1)
    // +model(2)+priority(1); its dims follow the ndim byte.
    let dims_off = 1 + 8 + 8 + 1 + 1 + 2 + 1 + 1;
    req[dims_off..dims_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(decode_request(&req).is_err());
}

#[test]
fn roundtrip_property_over_random_messages() {
    let mut rng = Rng::seeded(0x5EED_1234);
    for i in 0..400 {
        // Random tensor: rank 1..=3, dims 0..=4 (zero-row tensors are
        // legal on the wire — health responses and empty batches).
        let rank = 1 + rng.below(3);
        let dims: Vec<usize> = (0..rank).map(|_| rng.below(5)).collect();
        let n: usize = dims.iter().product();
        let data: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let tensor = Tensor::new(dims, data).unwrap();

        // Random request metadata.
        let token_len = rng.below(256);
        let model_len = rng.below(33);
        let mut req = InferRequest::infer(rng.next_u64(), "", tensor.clone());
        req.token = "t".repeat(token_len);
        req.model = "m".repeat(model_len);
        req.trace_id = rng.next_u64();
        req.sampled = rng.chance(0.5);
        req.priority = match rng.below(4) {
            0 => None,
            k => Some(Priority::ALL[k - 1]),
        };

        // Buffered path.
        let got = decode_request(&encode_request(&req)).unwrap();
        assert_eq!(got, req, "buffered request roundtrip, iteration {i}");
        // Streaming zero-copy path, with the session-stamped wire id.
        let wire_id = rng.next_u64();
        let mut framed = Vec::new();
        write_request_frame(&mut framed, &req, wire_id).unwrap();
        let frame = read_frame(&mut &framed[..]).unwrap().unwrap();
        let mut expected = req.clone();
        expected.request_id = wire_id;
        assert_eq!(
            decode_request(&frame).unwrap(),
            expected,
            "streaming request roundtrip, iteration {i}"
        );

        // Random response.
        let status = ALL_STATUSES[rng.below(ALL_STATUSES.len())];
        let resp = if status == Status::Ok {
            let mut r = InferResponse::ok(rng.next_u64(), tensor);
            r.queue_us = rng.next_u64() as u32;
            r.compute_us = rng.next_u64() as u32;
            r.batch_size = rng.next_u64() as u32;
            r
        } else {
            InferResponse::err(rng.next_u64(), status, "e".repeat(rng.below(1024)))
        };
        let got = decode_response(&encode_response(&resp)).unwrap();
        assert_eq!(got, resp, "buffered response roundtrip, iteration {i}");
        let mut framed = Vec::new();
        write_response_frame(&mut framed, &resp).unwrap();
        let frame = read_frame(&mut &framed[..]).unwrap().unwrap();
        assert_eq!(
            decode_response(&frame).unwrap(),
            resp,
            "streaming response roundtrip, iteration {i}"
        );
    }
}
