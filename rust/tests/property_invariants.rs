//! Property-based tests over coordinator invariants (routing, batching,
//! state management) using the in-crate `util::quick` helper.

use std::sync::{mpsc, Arc, RwLock};
use std::time::Duration;

use supersonic::config::{ExecutionMode, LbPolicy, ModelConfig, ServiceModelConfig};
use supersonic::gateway::lb::LoadBalancer;
use supersonic::metrics::Registry;
use supersonic::modelmesh::ModelRouter;
use supersonic::rpc::codec::{
    decode_request, decode_response, encode_request, encode_response, InferRequest,
    InferResponse, Priority, Status,
};
use supersonic::runtime::Tensor;
use supersonic::server::batcher::{BatchPolicy, BatchQueue, ExecOutcome, Pending};
use supersonic::server::{Instance, ModelRepository};
use supersonic::util::clock::Clock;
use supersonic::util::quick::{check, Gen};

fn pending(model: &str, rows: usize, clock: &Clock) -> (Pending, mpsc::Receiver<ExecOutcome>) {
    let (tx, rx) = mpsc::channel();
    (
        Pending {
            model: model.into(),
            priority: Priority::Standard,
            input: Tensor::zeros(vec![rows, 2]),
            enqueued: clock.now(),
            trace_id: 0,
            reply: tx,
        },
        rx,
    )
}

#[test]
fn prop_codec_roundtrip_any_request() {
    check("rpc request roundtrips", 300, |g: &mut Gen| {
        let rows = g.usize(1..=6);
        let cols = g.usize(1..=8);
        let data: Vec<f32> = (0..rows * cols).map(|_| g.f64(-1e6, 1e6) as f32).collect();
        let mut req = InferRequest::infer(
            g.u64(0..=u64::MAX),
            &format!("m{}", g.usize(0..=30)),
            Tensor::new(vec![rows, cols], data).unwrap(),
        );
        req.trace_id = g.u64(0..=u64::MAX);
        if g.bool() {
            req.token = "t".repeat(g.usize(0..=64));
        }
        let decoded = decode_request(&encode_request(&req)).unwrap();
        assert_eq!(decoded, req);
    });
}

#[test]
fn prop_codec_roundtrip_any_response() {
    check("rpc response roundtrips", 300, |g: &mut Gen| {
        let ok = g.bool();
        let resp = if ok {
            let rows = g.usize(1..=5);
            let data: Vec<f32> = (0..rows * 3).map(|_| g.f64(-10.0, 10.0) as f32).collect();
            let mut r = InferResponse::ok(
                g.u64(0..=u64::MAX),
                Tensor::new(vec![rows, 3], data).unwrap(),
            );
            r.queue_us = g.u64(0..=u32::MAX as u64) as u32;
            r.compute_us = g.u64(0..=u32::MAX as u64) as u32;
            r.batch_size = g.u64(1..=64) as u32;
            r
        } else {
            let statuses = [
                Status::Unauthorized,
                Status::RateLimited,
                Status::Overloaded,
                Status::BadRequest,
                Status::Internal,
                Status::ModelNotFound,
            ];
            InferResponse::err(
                g.u64(0..=u64::MAX),
                *g.choose(&statuses),
                "e".repeat(g.usize(0..=128)),
            )
        };
        let decoded = decode_response(&encode_response(&resp)).unwrap();
        assert_eq!(decoded, resp);
    });
}

#[test]
fn prop_codec_rejects_random_corruption() {
    check("corrupted frames never panic", 300, |g: &mut Gen| {
        let req = InferRequest::infer(7, "model", Tensor::zeros(vec![2, 3]));
        let mut buf = encode_request(&req);
        // flip up to 4 random bytes
        for _ in 0..g.usize(1..=4) {
            let i = g.usize(0..=buf.len() - 1);
            buf[i] ^= (1 + g.usize(0..=254)) as u8;
        }
        // must either decode to something or error — never panic
        let _ = decode_request(&buf);
    });
}

#[test]
fn prop_batcher_conserves_requests() {
    // Every pushed request is popped exactly once, same-model batches
    // only, batch row budget respected.
    check("batcher conserves requests", 60, |g: &mut Gen| {
        let clock = Clock::real();
        let q = BatchQueue::new(1024);
        let models = ["a", "b", "c"];
        let n = g.usize(1..=40);
        let mut pushed_per_model = std::collections::BTreeMap::new();
        let mut rxs = Vec::new();
        for _ in 0..n {
            let model = *g.choose(&models);
            let rows = g.usize(1..=5);
            let (p, rx) = pending(model, rows, &clock);
            q.push(p).map_err(|_| ()).unwrap();
            *pushed_per_model.entry(model.to_string()).or_insert(0usize) += rows;
            rxs.push(rx);
        }
        let max_rows = g.usize(4..=16);
        let preferred = g.usize(1..=max_rows);
        let mut popped_per_model = std::collections::BTreeMap::new();
        loop {
            let batch = q.pop_batch(
                &clock,
                |_| BatchPolicy {
                    max_queue_delay: Duration::from_millis(0),
                    preferred_rows: preferred,
                    max_rows,
                },
                Duration::from_millis(10),
            );
            let Some(batch) = batch else { break };
            assert!(!batch.is_empty());
            // same-model run
            let model = batch[0].model.clone();
            assert!(batch.iter().all(|p| p.model == model), "mixed-model batch");
            let rows: usize = batch.iter().map(|p| p.rows()).sum();
            // row budget respected unless a single oversized request
            assert!(
                rows <= max_rows || batch.len() == 1,
                "batch of {rows} rows exceeds budget {max_rows}"
            );
            *popped_per_model.entry(model).or_insert(0usize) += rows;
        }
        assert_eq!(pushed_per_model, popped_per_model, "requests lost or duplicated");
    });
}

#[test]
fn prop_lb_only_picks_ready_and_under_cap() {
    let repo = Arc::new(
        ModelRepository::load_metadata(
            std::path::Path::new("artifacts"),
            &["icecube_cnn".into()],
        )
        .unwrap(),
    );
    let clock = Clock::real();
    let registry = Registry::new();
    // Slow instances so submitted work stays in flight for the check.
    let mk = |id: &str| {
        Instance::start_with_mode(
            id,
            Arc::clone(&repo),
            &[ModelConfig {
                name: "icecube_cnn".into(),
                max_queue_delay: Duration::from_millis(1),
                preferred_batch: 1,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(50),
                    per_row: Duration::from_millis(1),
                },
                load_delay: None,
                backends: Vec::new(),
                ..ModelConfig::default()
            }],
            clock.clone(),
            registry.clone(),
            64,
            5.0,
            ExecutionMode::Simulated,
        )
    };

    check("lb picks only eligible instances", 25, |g: &mut Gen| {
        let n = g.usize(1..=5);
        let instances: Vec<Arc<Instance>> = (0..n).map(|i| mk(&format!("p{i}"))).collect();
        // randomly mark some ready, drain others
        let mut any_ready = false;
        for inst in &instances {
            if g.bool() {
                inst.mark_ready();
                any_ready = true;
            } else {
                inst.drain();
            }
        }
        let cap = g.usize(1..=3);
        let policies = [
            LbPolicy::RoundRobin,
            LbPolicy::Random,
            LbPolicy::LeastConnection,
            LbPolicy::UtilizationAware,
        ];
        let lb = LoadBalancer::new(
            *g.choose(&policies),
            Arc::new(RwLock::new(instances.clone())),
            cap,
            g.u64(0..=u64::MAX),
        );
        // saturate one ready instance to the cap
        let mut _rxs = Vec::new();
        if let Some(first_ready) = instances
            .iter()
            .find(|i| i.state() == supersonic::server::InstanceState::Ready)
        {
            for _ in 0..cap {
                if let Ok(rx) = first_ready.submit(
                    "icecube_cnn",
                    Tensor::zeros(vec![1, 16, 16, 3]),
                    0,
                ) {
                    _rxs.push(rx);
                }
            }
        }
        for _ in 0..10 {
            match lb.pick() {
                Some(picked) => {
                    assert_eq!(picked.state(), supersonic::server::InstanceState::Ready);
                    assert!(picked.inflight() < cap, "picked saturated instance");
                }
                None => {
                    // legal only if nothing is ready or everything saturated
                    let eligible = instances.iter().any(|i| {
                        i.state() == supersonic::server::InstanceState::Ready
                            && i.inflight() < cap
                    });
                    assert!(!eligible || !any_ready, "lb returned None with eligible instances");
                }
            }
        }
        for i in instances {
            i.stop();
        }
    });
}

#[test]
fn prop_router_only_routes_to_advertising_instances() {
    // The modelmesh invariant: across arbitrary load/unload/pod-churn
    // interleavings, a pick for model M only ever returns an instance
    // currently advertising M — and a submit to the picked instance is
    // never rejected with ModelNotFound.
    const MODELS: [&str; 2] = ["icecube_cnn", "particlenet"];
    let repo = Arc::new(
        supersonic::server::ModelRepository::load_metadata(
            std::path::Path::new("artifacts"),
            &MODELS.map(String::from),
        )
        .unwrap(),
    );
    let clock = Clock::real();
    let registry = Registry::new();
    let model_cfgs: Vec<ModelConfig> = MODELS
        .iter()
        .map(|m| ModelConfig {
            name: m.to_string(),
            max_queue_delay: Duration::from_millis(1),
            preferred_batch: 4,
            service_model: ServiceModelConfig {
                base: Duration::from_millis(1),
                per_row: Duration::from_micros(50),
            },
            load_delay: None,
            backends: Vec::new(),
            ..ModelConfig::default()
        })
        .collect();
    let mk = |id: &str| {
        let inst = Instance::start_with_mode(
            id,
            Arc::clone(&repo),
            &model_cfgs,
            clock.clone(),
            registry.clone(),
            64,
            5.0,
            ExecutionMode::Simulated,
        );
        inst.mark_ready();
        inst
    };
    let input_for = |model: &str| match model {
        "icecube_cnn" => Tensor::zeros(vec![1, 16, 16, 3]),
        _ => Tensor::zeros(vec![1, 64, 7]),
    };

    check("router only picks advertisers", 20, |g: &mut Gen| {
        let n = g.usize(1..=4);
        let instances: Vec<Arc<Instance>> =
            (0..n).map(|i| mk(&format!("mesh-p{i}"))).collect();
        let router = ModelRouter::new(
            &MODELS.map(String::from),
            *g.choose(&[LbPolicy::RoundRobin, LbPolicy::Random, LbPolicy::LeastConnection]),
            0,
            &Registry::new(),
            g.u64(0..=u64::MAX),
        );
        // random starting placement
        for inst in &instances {
            let keep: Vec<String> = MODELS
                .iter()
                .filter(|_| g.bool())
                .map(|m| m.to_string())
                .collect();
            inst.set_loaded_models(&keep);
        }
        router.sync(&instances);

        for _ in 0..40 {
            match g.usize(0..=3) {
                // load a model somewhere
                0 => {
                    let inst = &instances[g.usize(0..=n - 1)];
                    router.load(inst, g.choose(&MODELS));
                }
                // unload a model somewhere
                1 => {
                    let inst = &instances[g.usize(0..=n - 1)];
                    router.unload(inst, g.choose(&MODELS));
                }
                // pod churn: rebuild pools from a random endpoint subset
                2 => {
                    let subset: Vec<Arc<Instance>> =
                        instances.iter().filter(|_| g.bool()).cloned().collect();
                    router.sync(&subset);
                }
                // route a request
                _ => {
                    let model = *g.choose(&MODELS);
                    if let Ok(picked) = router.pick(model) {
                        assert!(
                            picked.advertises(model),
                            "picked {} for '{model}' which it does not advertise",
                            picked.id
                        );
                        // the instance accepts it (never ModelNotFound)
                        match picked.submit(model, input_for(model), 0) {
                            Ok(_rx) => {}
                            Err((status, _)) => assert_ne!(
                                status,
                                Status::ModelNotFound,
                                "advertising instance rejected '{model}'"
                            ),
                        }
                    }
                }
            }
        }
        // the terminal sync never resurrects unloaded models
        router.sync(&instances);
        for m in MODELS {
            for inst in router.endpoints_for(m) {
                assert!(inst.advertises(m));
            }
        }
        for i in instances {
            i.stop();
        }
    });
}

#[test]
fn prop_no_request_ever_routed_to_loading_replica() {
    // The warm-load invariant: across arbitrary load/unload/sync/pick
    // interleavings with REAL load windows, a pick for model M only ever
    // returns an instance where M is warm — never one still inside its
    // simulated load window — and submitting to the picked instance is
    // never rejected for a missing or loading model.
    const MODELS: [&str; 2] = ["icecube_cnn", "particlenet"];
    const LOAD_DELAY: Duration = Duration::from_millis(30);
    let repo = Arc::new(
        ModelRepository::load_metadata(
            std::path::Path::new("artifacts"),
            &MODELS.map(String::from),
        )
        .unwrap(),
    );
    let clock = Clock::real();
    let registry = Registry::new();
    let model_cfgs: Vec<ModelConfig> = MODELS
        .iter()
        .map(|m| ModelConfig {
            name: m.to_string(),
            max_queue_delay: Duration::from_millis(1),
            preferred_batch: 4,
            service_model: ServiceModelConfig {
                base: Duration::from_millis(1),
                per_row: Duration::from_micros(50),
            },
            load_delay: Some(LOAD_DELAY),
            backends: Vec::new(),
            ..ModelConfig::default()
        })
        .collect();
    let mk = |id: &str| {
        let inst = Instance::start_with_mode(
            id,
            Arc::clone(&repo),
            &model_cfgs,
            clock.clone(),
            registry.clone(),
            64,
            5.0,
            ExecutionMode::Simulated,
        );
        inst.mark_ready();
        inst
    };
    let input_for = |model: &str| match model {
        "icecube_cnn" => Tensor::zeros(vec![1, 16, 16, 3]),
        _ => Tensor::zeros(vec![1, 64, 7]),
    };

    check("no pick lands on a loading replica", 10, |g: &mut Gen| {
        let n = g.usize(1..=3);
        let instances: Vec<Arc<Instance>> =
            (0..n).map(|i| mk(&format!("warm-p{i}"))).collect();
        let router = ModelRouter::new(
            &MODELS.map(String::from),
            *g.choose(&[LbPolicy::RoundRobin, LbPolicy::Random, LbPolicy::LeastConnection]),
            0,
            &Registry::new(),
            g.u64(0..=u64::MAX),
        );
        // Random warm starting placement (set_loaded_models = bootstrap,
        // warm immediately).
        for inst in &instances {
            let keep: Vec<String> = MODELS
                .iter()
                .filter(|_| g.bool())
                .map(|m| m.to_string())
                .collect();
            inst.set_loaded_models(&keep);
        }
        router.sync(&instances);

        for _ in 0..40 {
            match g.usize(0..=4) {
                // start a (windowed) load somewhere
                0 => {
                    let inst = &instances[g.usize(0..=n - 1)];
                    let model = *g.choose(&MODELS);
                    let started = router.load(inst, model);
                    if started && !inst.advertises(model) {
                        // the window must keep it out of the pool
                        assert!(
                            !router
                                .endpoints_for(model)
                                .iter()
                                .any(|e| e.id == inst.id),
                            "loading replica {} joined the '{model}' pool",
                            inst.id
                        );
                    }
                }
                // unload (possibly canceling an in-flight load)
                1 => {
                    let inst = &instances[g.usize(0..=n - 1)];
                    router.unload(inst, g.choose(&MODELS));
                }
                // reconcile-style pool rebuild; admits freshly warm pods
                2 => router.sync(&instances),
                // let some windows expire
                3 => std::thread::sleep(Duration::from_millis(g.usize(1..=12) as u64)),
                // route a request
                _ => {
                    let model = *g.choose(&MODELS);
                    if let Ok(picked) = router.pick(model) {
                        assert!(
                            !picked.is_loading(model),
                            "picked {} for '{model}' while it was still loading",
                            picked.id
                        );
                        assert!(
                            picked.advertises(model),
                            "picked {} for '{model}' which is not warm there",
                            picked.id
                        );
                        match picked.submit(model, input_for(model), 0) {
                            Ok(_rx) => {}
                            Err((status, _)) => assert_ne!(
                                status,
                                Status::ModelNotFound,
                                "advertising instance rejected '{model}'"
                            ),
                        }
                    }
                }
            }
        }
        // Terminal settle: once every window has expired, a sync must
        // admit exactly the warm serving sets.
        std::thread::sleep(LOAD_DELAY + Duration::from_millis(10));
        router.sync(&instances);
        for m in MODELS {
            for inst in router.endpoints_for(m) {
                assert!(inst.advertises(m) && !inst.is_loading(m));
            }
        }
        for i in instances {
            i.stop();
        }
    });
}

#[test]
fn prop_planner_never_unloads_last_warm_copy() {
    use std::collections::{BTreeMap, BTreeSet};
    use supersonic::config::{ModelPlacementConfig, PlacementPolicy};
    use supersonic::modelmesh::{InstanceView, Move, PlacementCore};

    // The mid-move floor invariant: whatever the demand, budget, load
    // costs and mix of warm/loading copies, a single planning pass never
    // unloads a model's last warm copies (below the floor) — a model
    // whose replacement replica is still mid-load keeps serving from the
    // old one until the new one warms up.
    check("warm floor survives a planning pass", 300, |g: &mut Gen| {
        let n_models = g.usize(1..=3);
        let models: Vec<String> = (0..n_models).map(|m| format!("m{m}")).collect();
        let mem = 600_000u64;
        let catalog: Vec<(String, u64)> = models.iter().map(|m| (m.clone(), mem)).collect();
        let cfg = ModelPlacementConfig {
            policy: PlacementPolicy::Dynamic,
            // fits 1..=n_models models per instance (plus slack)
            memory_budget_mb: g.usize(1..=n_models) as f64 * 0.6 + 0.05,
            load_threshold: g.f64(50.0, 200.0),
            unload_threshold: g.f64(0.0, 40.0),
            cooldown: Duration::from_secs(g.usize(0..=5) as u64),
            demand_window: Duration::from_secs(10),
            min_replicas_per_model: 1,
            load_delay: Duration::ZERO,
        };
        let floor = cfg.min_replicas_per_model;
        let costs: BTreeMap<String, f64> = models
            .iter()
            .filter(|_| g.bool())
            .map(|m| (m.clone(), g.f64(0.0, 8.0)))
            .collect();
        let mut core = PlacementCore::with_load_costs(cfg, catalog, costs);

        let n_inst = g.usize(1..=5);
        let views: Vec<InstanceView> = (0..n_inst)
            .map(|i| {
                let mut warm = BTreeSet::new();
                let mut loading = BTreeSet::new();
                for m in &models {
                    match g.usize(0..=3) {
                        0 => {
                            warm.insert(m.clone());
                        }
                        1 => {
                            loading.insert(m.clone());
                        }
                        _ => {}
                    }
                }
                let mem_used = (warm.len() + loading.len()) as u64 * mem;
                InstanceView {
                    id: format!("i{i}"),
                    loaded: warm,
                    loading,
                    mem_used,
                    backends: BTreeSet::new(),
                }
            })
            .collect();
        let demand: BTreeMap<String, f64> =
            models.iter().map(|m| (m.clone(), g.f64(0.0, 500.0))).collect();

        let moves = core.plan(g.f64(0.0, 100.0), &views, &demand);

        // Replay the unloads against the warm counts.
        let mut warm_after: BTreeMap<&str, i64> = models
            .iter()
            .map(|m| {
                (
                    m.as_str(),
                    views.iter().filter(|v| v.loaded.contains(m)).count() as i64,
                )
            })
            .collect();
        for mv in &moves {
            if let Move::Unload { instance, model } = mv {
                let was_warm = views
                    .iter()
                    .find(|v| &v.id == instance)
                    .is_some_and(|v| v.loaded.contains(model));
                if was_warm {
                    *warm_after.get_mut(model.as_str()).unwrap() -= 1;
                }
            }
        }
        for m in &models {
            let before = views.iter().filter(|v| v.loaded.contains(m)).count() as i64;
            if before >= floor as i64 {
                assert!(
                    warm_after[m.as_str()] >= floor as i64,
                    "'{m}' dropped from {before} to {} warm copies (floor {floor}): {moves:?}",
                    warm_after[m.as_str()]
                );
            }
        }
    });
}

#[test]
fn prop_make_before_break_keeps_a_version_warm() {
    use std::collections::{BTreeMap, BTreeSet};
    use supersonic::config::{ModelPlacementConfig, PlacementPolicy};
    use supersonic::modelmesh::{InstanceView, Move, PlacementCore};
    use supersonic::server::split_version;

    // The version-lifecycle serving invariant: across random
    // interleavings of rollout direction flips (canary promote /
    // rollback, i.e. which version is retiring), pod churn, load
    // completions and planning passes, a plan's unloads never take a
    // base model from "some version warm somewhere" to "no version warm
    // anywhere". A retiring version may drain to zero copies — but only
    // make-before-break, once its successor holds a warm copy.
    check("make-before-break keeps a version warm per base", 250, |g: &mut Gen| {
        let n_bases = g.usize(1..=2);
        let bases: Vec<String> = (0..n_bases).map(|b| format!("m{b}")).collect();
        let versioned: Vec<String> = bases
            .iter()
            .flat_map(|b| [format!("{b}@v1"), format!("{b}@v2")])
            .collect();
        let mem = 600_000u64;
        let catalog: Vec<(String, u64)> = versioned.iter().map(|m| (m.clone(), mem)).collect();
        let cfg = ModelPlacementConfig {
            policy: PlacementPolicy::Dynamic,
            // fits 1..=4 versioned copies per instance (plus slack)
            memory_budget_mb: g.usize(1..=4) as f64 * 0.6 + 0.05,
            load_threshold: g.f64(50.0, 200.0),
            unload_threshold: g.f64(0.0, 40.0),
            cooldown: Duration::ZERO,
            demand_window: Duration::from_secs(10),
            min_replicas_per_model: 1,
            load_delay: Duration::ZERO,
        };
        let costs: BTreeMap<String, f64> = versioned
            .iter()
            .filter(|_| g.bool())
            .map(|m| (m.clone(), g.f64(0.0, 8.0)))
            .collect();
        let mut core = PlacementCore::with_load_costs(cfg, catalog, costs);

        // Fleet state we evolve by hand: per-instance warm + mid-load sets.
        let n_inst = g.usize(2..=4);
        let ids: Vec<String> = (0..n_inst).map(|i| format!("i{i}")).collect();
        let mut warm: BTreeMap<String, BTreeSet<String>> =
            ids.iter().map(|i| (i.clone(), BTreeSet::new())).collect();
        let mut loading: BTreeMap<String, BTreeSet<String>> =
            ids.iter().map(|i| (i.clone(), BTreeSet::new())).collect();
        // Seed: every base serves v1 somewhere; extra copies at random.
        for (k, b) in bases.iter().enumerate() {
            warm.get_mut(&ids[k % n_inst]).unwrap().insert(format!("{b}@v1"));
        }
        for id in &ids {
            for m in &versioned {
                match g.usize(0..=4) {
                    0 => {
                        warm.get_mut(id).unwrap().insert(m.clone());
                    }
                    1 => {
                        if !warm[id].contains(m) {
                            loading.get_mut(id).unwrap().insert(m.clone());
                        }
                    }
                    _ => {}
                }
            }
        }

        let warm_copies = |warm: &BTreeMap<String, BTreeSet<String>>, name: &str| {
            warm.values().filter(|set| set.iter().any(|m| split_version(m).0 == name || m == name)).count()
        };

        let mut now = 0.0;
        for _round in 0..6 {
            now += 1.0;
            // Lifecycle ops: flip each base's rollout direction at random
            // (promote = v1 retires into v2, rollback = v2 retires into
            // v1, steady = no retirement).
            for b in &bases {
                let (v1, v2) = (format!("{b}@v1"), format!("{b}@v2"));
                match g.usize(0..=3) {
                    0 => {
                        core.clear_successor(&v2);
                        core.set_successor(&v1, &v2);
                    }
                    1 => {
                        core.clear_successor(&v1);
                        core.set_successor(&v2, &v1);
                    }
                    2 => {
                        core.clear_successor(&v1);
                        core.clear_successor(&v2);
                    }
                    _ => {} // keep the previous direction
                }
            }
            // Pod churn: occasionally wipe one instance (crash).
            if g.usize(0..=4) == 0 {
                let victim = ids[g.usize(0..=n_inst - 1)].clone();
                warm.get_mut(&victim).unwrap().clear();
                loading.get_mut(&victim).unwrap().clear();
            }

            let views: Vec<InstanceView> = ids
                .iter()
                .map(|id| InstanceView {
                    id: id.clone(),
                    loaded: warm[id].clone(),
                    loading: loading[id].clone(),
                    mem_used: (warm[id].len() + loading[id].len()) as u64 * mem,
                    backends: BTreeSet::new(),
                })
                .collect();
            let demand: BTreeMap<String, f64> =
                versioned.iter().map(|m| (m.clone(), g.f64(0.0, 500.0))).collect();
            let moves = core.plan(now, &views, &demand);

            // Replay the plan's warm unloads per *base* name: whatever the
            // interleaving, a base that entered the round warm must leave
            // it warm (in some version, on some instance).
            let before: BTreeMap<&str, usize> =
                bases.iter().map(|b| (b.as_str(), warm_copies(&warm, b))).collect();
            for mv in &moves {
                match mv {
                    Move::Load { instance, model } => {
                        if !warm[instance].contains(model) {
                            loading.get_mut(instance).unwrap().insert(model.clone());
                        }
                    }
                    Move::Unload { instance, model } => {
                        warm.get_mut(instance).unwrap().remove(model);
                        loading.get_mut(instance).unwrap().remove(model);
                    }
                }
            }
            for b in &bases {
                if before[b.as_str()] >= 1 {
                    assert!(
                        warm_copies(&warm, b) >= 1,
                        "base '{b}' lost its last warm version to a planning pass \
                         (round state {warm:?}, moves {moves:?})"
                    );
                }
            }
            // Random subset of mid-loads warm up before the next round.
            for id in &ids {
                let done: Vec<String> =
                    loading[id].iter().filter(|_| g.bool()).cloned().collect();
                for m in done {
                    loading.get_mut(id).unwrap().remove(&m);
                    warm.get_mut(id).unwrap().insert(m);
                }
            }
        }
    });
}

#[test]
fn prop_yaml_display_parse_roundtrip() {
    use supersonic::config::yaml;
    check("yaml display/parse roundtrip", 150, |g: &mut Gen| {
        // Build a random nested value, render, reparse, compare.
        fn build(g: &mut Gen, depth: usize) -> yaml::Value {
            if depth == 0 || g.usize(0..=2) == 0 {
                match g.usize(0..=3) {
                    0 => yaml::Value::Int(g.u64(0..=1000) as i64),
                    1 => yaml::Value::Bool(g.bool()),
                    2 => yaml::Value::Str(format!("s{}", g.usize(0..=99))),
                    _ => yaml::Value::Null,
                }
            } else if g.bool() {
                let n = g.usize(1..=3);
                yaml::Value::Seq((0..n).map(|_| build(g, depth - 1)).collect())
            } else {
                let n = g.usize(1..=3);
                yaml::Value::Map(
                    (0..n).map(|i| (format!("k{i}"), build(g, depth - 1))).collect(),
                )
            }
        }
        let v = yaml::Value::Map(vec![("root".into(), build(g, 3))]);
        let rendered = v.to_string();
        let reparsed = yaml::parse(&rendered)
            .unwrap_or_else(|e| panic!("reparse failed: {e}\n{rendered}"));
        assert_eq!(v, reparsed, "roundtrip mismatch for:\n{rendered}");
    });
}

#[test]
fn prop_scale_down_never_starves_a_model_while_redundancy_exists() {
    use supersonic::orchestrator::select_scale_down_victims;
    check("placement-aware scale-down respects the floor", 400, |g: &mut Gen| {
        // Random serving-set layout.
        let n_models = g.usize(1..=4);
        let model = |m: usize| format!("m{m}");
        let mut sets = |count: usize| -> Vec<Vec<String>> {
            (0..count)
                .map(|_| (0..n_models).filter(|_| g.bool()).map(model).collect())
                .collect()
        };
        let candidate_sets = sets(g.usize(1..=8));
        let others = sets(g.usize(0..=4));
        let candidates: Vec<(String, Vec<String>)> = candidate_sets
            .into_iter()
            .enumerate()
            .map(|(i, models)| (format!("pod-{i}"), models))
            .collect();
        let floor = g.usize(1..=2);
        let count = g.usize(0..=candidates.len());

        let victims = select_scale_down_victims(&candidates, &others, count, floor);

        // The requested count always wins (Deployment semantics).
        assert_eq!(victims.len(), count.min(candidates.len()));
        // No duplicates, and every victim is a candidate.
        let mut seen = std::collections::BTreeSet::new();
        for v in &victims {
            assert!(seen.insert(v.clone()), "duplicate victim {v}");
            assert!(candidates.iter().any(|(n, _)| n == v), "unknown victim {v}");
        }

        // Replay the kills: at every step, if ANY remaining candidate is
        // redundant (killing it keeps all its models at >= floor
        // replicas), the chosen victim must be redundant too — a model
        // only ever drops below the floor when the layout forces it.
        let mut coverage = std::collections::BTreeMap::new();
        for models in candidates.iter().map(|(_, m)| m).chain(others.iter()) {
            for m in models {
                *coverage.entry(m.clone()).or_insert(0usize) += 1;
            }
        }
        let mut remaining: Vec<&(String, Vec<String>)> = candidates.iter().collect();
        for victim in &victims {
            let redundant = |models: &[String]| {
                models.iter().all(|m| coverage[m] > floor)
            };
            let any_redundant = remaining.iter().any(|(_, m)| redundant(m));
            let victim_models: Vec<String> = remaining
                .iter()
                .find(|(n, _)| n == victim)
                .expect("victim remains")
                .1
                .clone();
            if any_redundant {
                assert!(
                    redundant(&victim_models),
                    "killed {victim} (dropping {victim_models:?} below floor {floor}) \
                     while a redundant victim existed"
                );
            }
            for m in &victim_models {
                *coverage.get_mut(m).unwrap() -= 1;
            }
            remaining.retain(|(n, _)| n != victim);
        }
    });
}

#[test]
fn prop_priority_lanes_preserve_arrival_order_within_class() {
    // Within a model, arrival order still holds WITHIN a priority class:
    // across random interleavings of models, classes and row counts,
    // every popped sequence is strictly increasing in arrival order per
    // (model, priority) — the lanes reorder classes, never peers.
    check("arrival order holds within a priority", 40, |g: &mut Gen| {
        let clock = Clock::real();
        let q = BatchQueue::new(4096);
        let classes = [Priority::Bulk, Priority::Standard, Priority::Critical];
        let models = ["a", "b"];
        let mut rxs = Vec::new();
        for i in 0..g.usize(1..=40) {
            let model = *g.choose(&models);
            let (mut p, rx) = pending(model, g.usize(1..=3), &clock);
            p.priority = *g.choose(&classes);
            p.trace_id = i as u64;
            q.push(p).map_err(|_| ()).unwrap();
            rxs.push(rx);
        }
        let max_rows = g.usize(4..=16);
        let mut last_seen: std::collections::BTreeMap<(String, usize), u64> =
            std::collections::BTreeMap::new();
        loop {
            let batch = q.pop_batch(
                &clock,
                |_| BatchPolicy {
                    max_queue_delay: Duration::from_millis(0),
                    preferred_rows: max_rows,
                    max_rows,
                },
                Duration::from_millis(10),
            );
            let Some(batch) = batch else { break };
            let model = batch[0].model.clone();
            assert!(batch.iter().all(|p| p.model == model), "mixed-model batch");
            for p in &batch {
                let key = (model.clone(), p.priority.index());
                if let Some(&prev) = last_seen.get(&key) {
                    assert!(
                        p.trace_id > prev,
                        "{}-priority request {} served after {} within model '{model}'",
                        p.priority.name(),
                        p.trace_id,
                        prev
                    );
                }
                last_seen.insert(key, p.trace_id);
            }
        }
    });
}

#[test]
fn prop_critical_head_never_waits_behind_lower_priority_backlog() {
    // A critical request's max_queue_delay bound is never exceeded
    // because of a lower-priority batch: with an expired lower-priority
    // backlog longer than one batch ahead of it IN THE SAME MODEL, the
    // critical request is still part of the very first pop.
    check("critical head served in the first pop", 30, |g: &mut Gen| {
        let clock = Clock::real();
        let q = BatchQueue::new(4096);
        let mut rxs = Vec::new();
        // Lower-priority backlog well beyond one batch's row budget.
        let max_rows = g.usize(4..=8);
        let lower = [Priority::Bulk, Priority::Standard];
        for i in 0..g.usize(6..=20) {
            let (mut p, rx) = pending("m", g.usize(2..=4), &clock);
            p.priority = *g.choose(&lower);
            p.trace_id = i as u64;
            q.push(p).map_err(|_| ()).unwrap();
            rxs.push(rx);
        }
        let (mut pc, _rc) = pending("m", 1, &clock);
        pc.priority = Priority::Critical;
        pc.trace_id = 999;
        q.push(pc).map_err(|_| ()).unwrap();
        // Everything expires (5 ms window), so a priority-blind batcher
        // would drain the backlog in arrival order across several pops
        // before reaching the critical request.
        std::thread::sleep(Duration::from_millis(10));
        let batch = q
            .pop_batch(
                &clock,
                |_| BatchPolicy {
                    max_queue_delay: Duration::from_millis(5),
                    preferred_rows: max_rows,
                    max_rows,
                },
                Duration::from_millis(200),
            )
            .unwrap();
        assert_eq!(
            batch[0].trace_id, 999,
            "critical request waited behind a lower-priority batch"
        );
    });
}

#[test]
fn prop_shed_from_bulk_never_evicts_equal_or_higher_priority() {
    // Overload eviction only ever removes STRICTLY lower-priority
    // requests than the incoming one, and the row bound holds after
    // every successful push.
    check("shed-from-bulk evicts only lower classes", 60, |g: &mut Gen| {
        let clock = Clock::real();
        let capacity = g.usize(4..=12);
        let q = BatchQueue::new(capacity);
        let classes = [Priority::Bulk, Priority::Standard, Priority::Critical];
        let models = ["a", "b"];
        let mut rxs = Vec::new();
        for i in 0..g.usize(5..=30) {
            let model = *g.choose(&models);
            let (mut p, rx) = pending(model, g.usize(1..=3), &clock);
            let incoming = *g.choose(&classes);
            p.priority = incoming;
            p.trace_id = i as u64;
            match q.push(p) {
                Ok(evicted) => {
                    for victim in &evicted {
                        assert!(
                            victim.priority < incoming,
                            "{}-priority push evicted a {}-priority request",
                            incoming.name(),
                            victim.priority.name()
                        );
                    }
                    assert!(
                        q.rows_queued() <= capacity,
                        "row bound violated after admission: {} > {capacity}",
                        q.rows_queued()
                    );
                }
                Err(_) => {
                    // Rejection is only legal when the incoming request
                    // could not fit even after shedding every strictly
                    // lower-priority row.
                }
            }
            rxs.push(rx);
        }
    });
}

#[test]
fn prop_planner_never_lands_model_on_incompatible_backend() {
    use std::collections::{BTreeMap, BTreeSet};
    use supersonic::config::{ModelPlacementConfig, PlacementPolicy};
    use supersonic::modelmesh::{InstanceView, Move, PlacementCore};

    // The backend-compatibility invariant: whatever the demand, memory
    // budget and fleet mix, a planning pass (repairs included) never
    // plans a Load of a model onto an instance whose backend set does
    // not intersect the model's preference list.
    check("placement respects backend compatibility", 300, |g: &mut Gen| {
        let n_models = g.usize(1..=3);
        let models: Vec<String> = (0..n_models).map(|m| format!("m{m}")).collect();
        let mem = 600_000u64;
        let catalog: Vec<(String, u64)> = models.iter().map(|m| (m.clone(), mem)).collect();
        // Random non-empty preference list per model.
        let compat: BTreeMap<String, Vec<String>> = models
            .iter()
            .map(|m| {
                let prefs = match g.usize(0..=2) {
                    0 => vec!["pjrt".to_string()],
                    1 => vec!["onnx-sim".to_string()],
                    _ => vec!["pjrt".to_string(), "onnx-sim".to_string()],
                };
                (m.clone(), prefs)
            })
            .collect();
        let cfg = ModelPlacementConfig {
            policy: PlacementPolicy::Dynamic,
            memory_budget_mb: g.usize(1..=n_models) as f64 * 0.6 + 0.05,
            load_threshold: g.f64(50.0, 200.0),
            unload_threshold: g.f64(0.0, 40.0),
            cooldown: Duration::from_secs(g.usize(0..=5) as u64),
            demand_window: Duration::from_secs(10),
            min_replicas_per_model: 1,
            load_delay: Duration::ZERO,
        };
        let mut core = PlacementCore::with_backends(cfg, catalog, BTreeMap::new(), compat.clone());

        let n_inst = g.usize(1..=5);
        let views: Vec<InstanceView> = (0..n_inst)
            .map(|i| {
                // gpu pod, cpu pod, or dual-class pod
                let backends: BTreeSet<String> = match g.usize(0..=2) {
                    0 => ["pjrt".to_string()].into(),
                    1 => ["onnx-sim".to_string()].into(),
                    _ => ["pjrt".to_string(), "onnx-sim".to_string()].into(),
                };
                let mut warm = BTreeSet::new();
                let mut loading = BTreeSet::new();
                for m in &models {
                    // only seed placements that are themselves legal
                    let hostable = compat[m].iter().any(|b| backends.contains(b));
                    if hostable {
                        match g.usize(0..=3) {
                            0 => {
                                warm.insert(m.clone());
                            }
                            1 => {
                                loading.insert(m.clone());
                            }
                            _ => {}
                        }
                    }
                }
                let mem_used = (warm.len() + loading.len()) as u64 * mem;
                InstanceView { id: format!("i{i}"), loaded: warm, loading, mem_used, backends }
            })
            .collect();
        let demand: BTreeMap<String, f64> =
            models.iter().map(|m| (m.clone(), g.f64(0.0, 500.0))).collect();

        let moves = core.plan(g.f64(0.0, 100.0), &views, &demand);
        for mv in &moves {
            if let Move::Load { instance, model } = mv {
                let view = views.iter().find(|v| &v.id == instance).expect("known instance");
                assert!(
                    compat[model].iter().any(|b| view.backends.contains(b)),
                    "planned '{model}' onto {instance} (backends {:?}) without a \
                     compatible backend: {moves:?}",
                    view.backends
                );
            }
        }
    });
}

#[test]
fn prop_backend_selection_stays_in_preference_list() {
    use supersonic::config::{EnginesConfig, ModelConfig};
    use supersonic::engine::{BackendRegistry, EngineCatalog};

    // Fallback selection never invents a backend: whatever subset of
    // backends an instance advertises, the selected backend is in the
    // model's preference list, at the first available rank.
    let registry = BackendRegistry::default();
    check("backend selection stays in the preference list", 200, |g: &mut Gen| {
        let prefs: Vec<String> = match g.usize(0..=3) {
            0 => vec!["pjrt".into()],
            1 => vec!["onnx-sim".into()],
            2 => vec!["pjrt".into(), "onnx-sim".into()],
            _ => vec!["onnx-sim".into(), "pjrt".into()],
        };
        let model = ModelConfig {
            name: "m".into(),
            backends: prefs.clone(),
            ..ModelConfig::default()
        };
        let catalog =
            EngineCatalog::resolve(std::slice::from_ref(&model), &EnginesConfig::default());
        let available: Vec<_> =
            registry.backends().iter().filter(|_| g.bool()).cloned().collect();
        match catalog.select("m", &available) {
            None => {
                // legal only when nothing available is compatible
                assert!(
                    !available.iter().any(|b| prefs.iter().any(|p| p == b.name())),
                    "selection refused although {prefs:?} intersects the available set"
                );
            }
            Some((backend, rank)) => {
                assert_eq!(
                    prefs[rank], backend.name(),
                    "rank does not index the preference list"
                );
                // nothing earlier in the preference list was available
                for earlier in &prefs[..rank] {
                    assert!(
                        !available.iter().any(|b| b.name() == earlier.as_str()),
                        "fallback to rank {rank} although '{earlier}' was available"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_cpu_only_model_never_served_by_gpu_instance() {
    use supersonic::config::EnginesConfig;
    use supersonic::engine::{AcceleratorClass, BackendRegistry, EngineCatalog};
    use supersonic::server::InstanceOptions;

    // The acceptance-criterion invariant, end to end: a model configured
    // `backends: [onnx-sim]` is never placed on, routed to, or executed
    // by a PJRT-only (GPU-class) instance — across arbitrary
    // load/unload/sync/pick interleavings.
    const CPU_ONLY: &str = "icecube_cnn";
    const MODELS: [&str; 2] = ["icecube_cnn", "particlenet"];
    let repo = Arc::new(
        ModelRepository::load_metadata(
            std::path::Path::new("artifacts"),
            &MODELS.map(String::from),
        )
        .unwrap(),
    );
    let clock = Clock::real();
    let registry = Registry::new();
    let model_cfgs: Vec<ModelConfig> = MODELS
        .iter()
        .map(|m| ModelConfig {
            name: m.to_string(),
            max_queue_delay: Duration::from_millis(1),
            preferred_batch: 4,
            service_model: ServiceModelConfig {
                base: Duration::from_millis(1),
                per_row: Duration::from_micros(50),
            },
            load_delay: None,
            backends: if *m == CPU_ONLY {
                vec!["onnx-sim".into()]
            } else {
                Vec::new()
            },
            ..ModelConfig::default()
        })
        .collect();
    let engine_catalog = Arc::new(EngineCatalog::resolve(&model_cfgs, &EnginesConfig::default()));
    let backend_registry = BackendRegistry::default();
    let mk = |id: &str, class: AcceleratorClass| {
        let inst = Instance::start_with_opts(
            id,
            Arc::clone(&repo),
            &model_cfgs,
            clock.clone(),
            registry.clone(),
            InstanceOptions {
                exec_mode: ExecutionMode::Simulated,
                backends: backend_registry.for_class(class),
                catalog: Arc::clone(&engine_catalog),
                ..Default::default()
            },
        );
        inst.mark_ready();
        inst
    };
    let input_for = |model: &str| match model {
        "icecube_cnn" => Tensor::zeros(vec![1, 16, 16, 3]),
        _ => Tensor::zeros(vec![1, 64, 7]),
    };

    check("cpu-only model never lands on a gpu instance", 15, |g: &mut Gen| {
        let n = g.usize(2..=4);
        let instances: Vec<(Arc<Instance>, AcceleratorClass)> = (0..n)
            .map(|i| {
                let class = if g.bool() { AcceleratorClass::Gpu } else { AcceleratorClass::Cpu };
                (mk(&format!("ht-p{i}-{}", class.name()), class), class)
            })
            .collect();
        let endpoints: Vec<Arc<Instance>> =
            instances.iter().map(|(i, _)| Arc::clone(i)).collect();
        let router = ModelRouter::new(
            &MODELS.map(String::from),
            *g.choose(&[LbPolicy::RoundRobin, LbPolicy::Random, LbPolicy::LeastConnection]),
            0,
            &Registry::new(),
            g.u64(0..=u64::MAX),
        );
        router.sync(&endpoints);

        for _ in 0..40 {
            match g.usize(0..=3) {
                0 => {
                    let (inst, class) = &instances[g.usize(0..=n - 1)];
                    router.load(inst, g.choose(&MODELS));
                    if *class == AcceleratorClass::Gpu {
                        assert!(
                            !inst.serving_set().contains(&CPU_ONLY.to_string()),
                            "{}: a load put the CPU-only model on a gpu instance",
                            inst.id
                        );
                    }
                }
                1 => {
                    let (inst, _) = &instances[g.usize(0..=n - 1)];
                    router.unload(inst, g.choose(&MODELS));
                }
                2 => router.sync(&endpoints),
                _ => {
                    let model = *g.choose(&MODELS);
                    if let Ok(picked) = router.pick(model) {
                        if model == CPU_ONLY {
                            assert!(
                                picked.backend_names().contains(&"onnx-sim".to_string()),
                                "routed the CPU-only model to {} (backends {:?})",
                                picked.id,
                                picked.backend_names()
                            );
                            assert_eq!(
                                picked.backend_for_model(model).as_deref(),
                                Some("onnx-sim")
                            );
                        }
                        match picked.submit(model, input_for(model), 0) {
                            Ok(_rx) => {}
                            Err((status, _)) => assert_ne!(status, Status::ModelNotFound),
                        }
                    }
                }
            }
        }
        // Invariants hold at the end, for every GPU-class instance.
        for (inst, class) in &instances {
            if *class == AcceleratorClass::Gpu {
                assert!(
                    !inst.serving_set().contains(&CPU_ONLY.to_string()),
                    "{} (gpu) holds the CPU-only model",
                    inst.id
                );
                assert!(!inst.load_model(CPU_ONLY), "gpu instance accepted a cpu-only load");
                match inst.submit(CPU_ONLY, input_for(CPU_ONLY), 0) {
                    Ok(_) => panic!("{} (gpu) executed the CPU-only model", inst.id),
                    Err((status, _)) => assert_eq!(status, Status::ModelNotFound),
                }
            }
        }
        for (inst, _) in instances {
            inst.stop();
        }
    });
}

#[test]
fn prop_aged_bulk_request_served_within_the_bound() {
    use supersonic::config::BatchMode;

    // The anti-starvation guarantee: under sustained critical pressure,
    // every bulk request is still served within max_bulk_wait (plus
    // scheduling slack) — and, with a wide un-fillable batching window,
    // not meaningfully before it (the promotion is what serves it).
    const BOUND: Duration = Duration::from_millis(60);
    check("aged bulk served within the aging bound", 8, |g: &mut Gen| {
        let clock = Clock::real();
        let q = BatchQueue::with_aging(4096, BatchMode::Affinity, BOUND);
        // Bulk requests on their own models, wide 5 s windows, a target
        // they never fill: only aging can serve them.
        let n_bulk = g.usize(1..=3);
        let mut bulk_rxs = Vec::new();
        let pushed_at = std::time::Instant::now();
        for i in 0..n_bulk {
            let (tx, rx) = mpsc::channel();
            q.push(Pending {
                model: format!("bulk{i}"),
                priority: Priority::Bulk,
                input: Tensor::zeros(vec![g.usize(1..=3), 2]),
                enqueued: clock.now(),
                trace_id: 1000 + i as u64,
                reply: tx,
            })
            .map_err(|_| ())
            .unwrap();
            bulk_rxs.push(rx);
        }
        let policy = |model: &str| BatchPolicy {
            max_queue_delay: if model.starts_with("bulk") {
                Duration::from_secs(5)
            } else {
                Duration::from_millis(1)
            },
            preferred_rows: 64,
            max_rows: 64,
        };
        // Sustained critical pressure: push + pop in a tight loop until
        // every bulk request has been popped.
        let mut served_at: Vec<Option<Duration>> = vec![None; n_bulk];
        let deadline = std::time::Instant::now() + Duration::from_secs(3);
        let mut _crit_rxs = Vec::new();
        while served_at.iter().any(|s| s.is_none()) {
            assert!(std::time::Instant::now() < deadline, "bulk starved: {served_at:?}");
            let (tx, rx) = mpsc::channel();
            q.push(Pending {
                model: "crit".into(),
                priority: Priority::Critical,
                input: Tensor::zeros(vec![1, 2]),
                enqueued: clock.now(),
                trace_id: 0,
                reply: tx,
            })
            .map_err(|_| ())
            .unwrap();
            _crit_rxs.push(rx);
            std::thread::sleep(Duration::from_millis(2));
            let batch = q
                .pop_batch(&clock, policy, Duration::from_millis(50))
                .expect("work is queued");
            for p in &batch {
                if p.trace_id >= 1000 {
                    served_at[(p.trace_id - 1000) as usize] = Some(pushed_at.elapsed());
                }
            }
        }
        for (i, served) in served_at.iter().enumerate() {
            let served = served.unwrap();
            // Each aged head is promoted within one pop of crossing the
            // bound; with up to 3 bulk lanes and ~2 ms pop cadence, a
            // generous scheduling slack still pins the bound.
            assert!(
                served <= BOUND + Duration::from_millis(400),
                "bulk{i} served only after {served:?} (bound {BOUND:?})"
            );
            assert!(
                served >= Duration::from_millis(40),
                "bulk{i} served at {served:?} — before aging could have promoted it"
            );
        }
    });
}

#[test]
fn prop_tensor_stack_slice_roundtrip() {
    check("tensor stack/slice roundtrip", 200, |g: &mut Gen| {
        let cols = g.usize(1..=6);
        let parts: Vec<Tensor> = (0..g.usize(1..=5))
            .map(|_| {
                let rows = g.usize(1..=4);
                let data: Vec<f32> =
                    (0..rows * cols).map(|_| g.f64(-100.0, 100.0) as f32).collect();
                Tensor::new(vec![rows, cols], data).unwrap()
            })
            .collect();
        let total: usize = parts.iter().map(|t| t.batch()).sum();
        let pad_to = total + g.usize(0..=4);
        let stacked = Tensor::stack_padded(&parts, pad_to).unwrap();
        assert_eq!(stacked.shape(), &[pad_to, cols]);
        let mut offset = 0;
        for p in &parts {
            let s = stacked.slice_rows(offset, p.batch()).unwrap();
            assert_eq!(s.data(), p.data(), "slice mismatch");
            offset += p.batch();
        }
        // padding rows are zeros
        if pad_to > total {
            let pad = stacked.slice_rows(total, pad_to - total).unwrap();
            assert!(pad.data().iter().all(|&v| v == 0.0));
        }
    });
}

#[test]
fn prop_trace_breakdown_reconstructs_root() {
    use supersonic::telemetry::{Span, Tracer, ROOT_SPAN, STAGES};

    // Stage spans laid out sequentially inside a root window must come
    // back well-formed (end >= start, inside the root) and the critical-
    // path breakdown must reconstruct the root duration exactly, with
    // `other` absorbing the uncovered gaps.
    check("trace breakdown reconstructs the root", 200, |g: &mut Gen| {
        let tracer = Tracer::new(Clock::simulated(), 65536, true);
        let named: Vec<&str> = STAGES.iter().copied().filter(|&s| s != "other").collect();
        let trace_id = g.u64(1..=u64::MAX);
        let root_start = g.f64(0.0, 100.0);
        let root_end = root_start + g.f64(0.001, 50.0);
        let mut expected: std::collections::BTreeMap<&str, f64> =
            named.iter().map(|&s| (s, 0.0)).collect();
        let mut t = root_start;
        for _ in 0..g.usize(0..=10) {
            let rem = root_end - t;
            if rem <= 1e-9 {
                break;
            }
            let gap = g.f64(0.0, rem / 4.0); // uncovered time -> "other"
            let dur = g.f64(0.0, rem - gap);
            let name = *g.choose(&named);
            tracer.record(Span {
                trace_id,
                name: name.into(),
                start: t + gap,
                end: t + gap + dur,
            });
            *expected.get_mut(name).unwrap() += (t + gap + dur) - (t + gap);
            t += gap + dur;
        }
        tracer.record(Span {
            trace_id,
            name: ROOT_SPAN.into(),
            start: root_start,
            end: root_end,
        });

        let view = tracer.trace(trace_id);
        assert!(!view.is_partial(), "nothing was evicted");
        for s in &view.spans {
            assert!(s.end >= s.start, "span '{}' ends before it starts", s.name);
            assert!(
                s.start >= root_start - 1e-9 && s.end <= root_end + 1e-9,
                "span '{}' escapes the root window",
                s.name
            );
        }
        let rows = view.stage_breakdown().expect("complete trace with a root span");
        let root_dur = view.root_duration().unwrap();
        for (stage, d) in &rows {
            assert!(*d >= 0.0, "negative duration for stage '{stage}'");
            if *stage != "other" {
                let want = expected[stage];
                assert!(
                    (d - want).abs() <= 1e-9 * (1.0 + want),
                    "stage '{stage}': breakdown {d} != recorded {want}"
                );
            }
        }
        let total: f64 = rows.iter().map(|(_, d)| d).sum();
        assert!(
            (total - root_dur).abs() <= 1e-6 * (1.0 + root_dur),
            "stage sum {total} does not reconstruct root {root_dur}"
        );

        // Same invariants through the RAII guard path on a simulated
        // clock: nested stage guards can never overlap-exceed the root.
        let clock = Clock::simulated();
        let guarded = Tracer::new(clock.clone(), 65536, true);
        let tid = g.u64(1..=u64::MAX);
        {
            let _root = guarded.span(tid, ROOT_SPAN).unwrap();
            for _ in 0..g.usize(0..=5) {
                let stage = guarded.span(tid, *g.choose(&named)).unwrap();
                clock.advance(Duration::from_micros(g.u64(0..=100_000)));
                drop(stage);
            }
        }
        let view = guarded.trace(tid);
        assert!(view.spans.iter().all(|s| s.end >= s.start));
        let rows = view.stage_breakdown().expect("root guard recorded");
        assert!(rows.iter().all(|(_, d)| *d >= 0.0));
        let total: f64 = rows.iter().map(|(_, d)| d).sum();
        let root = view.root_duration().unwrap();
        assert!(
            (total - root).abs() <= 1e-6 * (1.0 + root),
            "guard-path stage sum {total} != root {root}"
        );
    });
}

#[test]
fn prop_stage_histogram_exposition_monotone_and_consistent() {
    use supersonic::metrics::exposition::render;
    use supersonic::metrics::registry::labels;
    use supersonic::telemetry::{STAGES, STAGE_HISTOGRAM};

    // The Prometheus text rendering of the stage histograms must keep
    // cumulative bucket counts monotone, close at `+Inf` with the
    // observation count, and agree with `_sum`/`_count` — for any mix of
    // observations, including ones past the last finite bucket bound.
    check("stage exposition monotone and consistent", 100, |g: &mut Gen| {
        let registry = Registry::new();
        let mut expected: Vec<(&str, u64, f64)> = Vec::new();
        for &stage in STAGES {
            let h = registry.histogram(STAGE_HISTOGRAM, &labels(&[("stage", stage)]));
            let n = g.usize(0..=25);
            let mut sum = 0.0;
            for _ in 0..n {
                let v = g.f64(0.0, 200.0); // last finite bound is ~65 s
                h.observe(v);
                sum += v;
            }
            expected.push((stage, n as u64, sum));
        }
        let text = render(&registry);
        for (stage, n, sum) in expected {
            let bucket_prefix = format!("{STAGE_HISTOGRAM}_bucket{{stage=\"{stage}\",le=");
            let buckets: Vec<u64> = text
                .lines()
                .filter(|l| l.starts_with(&bucket_prefix))
                .map(|l| l.split_whitespace().last().unwrap().parse().unwrap())
                .collect();
            assert!(!buckets.is_empty(), "no bucket lines for stage '{stage}'");
            assert!(
                buckets.windows(2).all(|w| w[0] <= w[1]),
                "buckets not cumulative for stage '{stage}': {buckets:?}"
            );
            assert_eq!(
                *buckets.last().unwrap(),
                n,
                "+Inf bucket disagrees with observation count for '{stage}'"
            );
            let value_of = |suffix: &str| -> f64 {
                let prefix = format!("{STAGE_HISTOGRAM}{suffix}{{stage=\"{stage}\"}} ");
                text.lines()
                    .find(|l| l.starts_with(&prefix))
                    .unwrap_or_else(|| panic!("missing series {prefix}"))
                    .split_whitespace()
                    .last()
                    .unwrap()
                    .parse()
                    .unwrap()
            };
            assert_eq!(value_of("_count") as u64, n, "_count mismatch for '{stage}'");
            let rendered_sum = value_of("_sum");
            assert!(
                (rendered_sum - sum).abs() <= 1e-9 * (1.0 + sum.abs()),
                "_sum for '{stage}': rendered {rendered_sum} vs observed {sum}"
            );
        }
    });
}
