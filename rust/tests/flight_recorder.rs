//! Flight-recorder property test: across randomized interleavings of
//! load bursts, site failure/recovery (whole-site pod churn) and idle
//! settling, every control-loop mutation that is observable through
//! public state must have a matching [`DecisionEvent`] in the recorder —
//! and the ledger itself must stay well-formed (bounded, time-ordered,
//! label vocabulary closed over the declared catalogs).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use supersonic::config::{
    AutoscalerConfig, ClusterConfig, DeploymentConfig, ExecutionMode, FederationConfig,
    GatewayConfig, ModelConfig, ModelPlacementConfig, MonitoringConfig, PerModelScalingConfig,
    ServerConfig, ServiceModelConfig, SiteConfig,
};
use supersonic::deployment::Deployment;
use supersonic::rpc::client::RpcClient;
use supersonic::rpc::codec::Status;
use supersonic::runtime::Tensor;
use supersonic::telemetry::flight::{DecisionEvent, FlightRecorder, DECISION_KINDS, LOOP_LABELS};
use supersonic::util::quick::{check, Gen};

const SITES: [&str; 3] = ["purdue", "nrp", "uchicago"];
const HOME: &str = "purdue";

fn site(name: &str, wan: &[(&str, f64)]) -> SiteConfig {
    SiteConfig {
        name: name.into(),
        pod_budget: 4,
        replicas: 2,
        nodes: 2,
        gpus_per_node: 2,
        cpu_replicas: 0,
        wan: wan
            .iter()
            .map(|(peer, secs)| (peer.to_string(), Duration::from_secs_f64(*secs)))
            .collect::<BTreeMap<_, _>>(),
    }
}

fn fed_cfg() -> DeploymentConfig {
    DeploymentConfig {
        name: "flighttest".into(),
        server: ServerConfig {
            replicas: 2,
            models: vec![ModelConfig {
                name: "icecube_cnn".into(),
                max_queue_delay: Duration::from_millis(1),
                preferred_batch: 8,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(2),
                    per_row: Duration::from_micros(100),
                },
                ..ModelConfig::default()
            }],
            repository: "artifacts".into(),
            startup_delay: Duration::from_millis(10),
            execution: ExecutionMode::Simulated,
            queue_capacity: 256,
            util_window: 5.0,
            batch_mode: Default::default(),
            priorities: Default::default(),
        },
        gateway: GatewayConfig::default(),
        autoscaler: AutoscalerConfig {
            enabled: true,
            min_replicas: 1,
            max_replicas: 6,
            poll_interval: Duration::from_millis(100),
            per_model: PerModelScalingConfig {
                enabled: true,
                // Scale-ups are not the subject here: keep pod counts
                // stable so the induced mutations are the ones we check.
                threshold: 10_000.0,
                min_replicas: 1,
                max_replicas: 4,
            },
            ..AutoscalerConfig::default()
        },
        cluster: ClusterConfig {
            nodes: 3,
            gpus_per_node: 2,
            pod_start_delay: Duration::from_millis(20),
            termination_grace: Duration::from_millis(20),
            pod_failure_rate: 0.0,
        },
        federation: FederationConfig {
            sites: vec![
                site(HOME, &[("nrp", 0.002), ("uchicago", 0.004)]),
                site("nrp", &[]),
                site("uchicago", &[]),
            ],
            gateway_site: HOME.into(),
            rebalance_interval: Duration::from_millis(200),
            spillover_queue_depth: 8.0,
        },
        monitoring: MonitoringConfig {
            listen: String::new(),
            scrape_interval: Duration::from_millis(100),
            retention: Duration::from_secs(600),
            tracing: false,
        },
        model_placement: ModelPlacementConfig {
            memory_budget_mb: 4096.0,
            ..ModelPlacementConfig::default()
        },
        engines: Default::default(),
        observability: Default::default(),
        rpc: Default::default(),
        time_scale: 4.0,
    }
}

fn wait_for(timeout: Duration, probe: impl Fn() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if probe() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    probe()
}

/// True when an event of `kind` for `site` exists at or after `since`
/// (clock seconds).
fn has_event(flight: &FlightRecorder, kind: &str, site: &str, since: f64) -> bool {
    flight
        .events()
        .iter()
        .any(|e| e.kind == kind && e.site.as_deref() == Some(site) && e.at >= since)
}

fn burst(addr: &str, n: usize) {
    let mut client = match RpcClient::connect(addr) {
        Ok(c) => c,
        Err(_) => return,
    };
    for _ in 0..n {
        match client.infer("icecube_cnn", Tensor::zeros(vec![1, 16, 16, 3])) {
            Ok(resp) if resp.status == Status::Ok => {}
            // A dead gateway stream needs a fresh connection.
            _ => match RpcClient::connect(addr) {
                Ok(c) => client = c,
                Err(_) => return,
            },
        }
    }
}

/// The ledger must be structurally sound no matter what happened:
/// stamped in time order, bounded, and closed over the declared loop and
/// kind vocabularies (the docs gates cover exactly these catalogs).
fn assert_ledger_well_formed(events: &[DecisionEvent]) {
    let mut prev = f64::NEG_INFINITY;
    for e in events {
        assert!(
            LOOP_LABELS.contains(&e.loop_name),
            "undeclared loop label '{}' in the ledger",
            e.loop_name
        );
        assert!(
            DECISION_KINDS.contains(&e.kind),
            "undeclared decision kind '{}' in the ledger",
            e.kind
        );
        assert!(
            e.at >= prev,
            "ledger out of time order: {} after {prev}",
            e.at
        );
        prev = e.at;
    }
}

#[test]
fn every_observable_mutation_has_a_decision_event() {
    // Two seeded iterations: a random interleaving prefix for variety,
    // then a forced kill -> burst -> recover tail so every run exercises
    // the full outage chain.
    check("flight_recorder_ledger", 2, |g: &mut Gen| {
        let d = Deployment::up(fed_cfg()).unwrap();
        let fed = Arc::clone(d.federation.as_ref().expect("federated deployment"));
        let flight = Arc::clone(d.flight.as_ref().expect("recorder armed by default"));
        assert!(d.wait_ready(6, Duration::from_secs(10)), "federation never became ready");
        let addr = d.endpoint();

        // Random interleaving prefix: bursts, kills, recoveries, settles.
        let mut down = [false; 3];
        for _ in 0..g.u64(2..=4) {
            match g.u64(0..=3) {
                0 => burst(&addr, 20),
                1 => {
                    let i = g.u64(0..=2) as usize;
                    if !down[i] && down.iter().filter(|&&x| x).count() < 2 {
                        let t0 = d.clock.now_secs();
                        assert!(fed.fail_site(SITES[i]));
                        down[i] = true;
                        // Drain, then the rebalancer must ledger the outage.
                        assert!(
                            wait_for(Duration::from_secs(10), || {
                                fed.running_by_site().get(SITES[i]) == Some(&0)
                            }),
                            "site '{}' never drained",
                            SITES[i]
                        );
                        assert!(
                            wait_for(Duration::from_secs(5), || {
                                has_event(&flight, "site_outage", SITES[i], t0)
                            }),
                            "site '{}' drained with no site_outage event",
                            SITES[i]
                        );
                    }
                }
                2 => {
                    let i = g.u64(0..=2) as usize;
                    if down[i] {
                        let t0 = d.clock.now_secs();
                        assert!(fed.recover_site(SITES[i]));
                        down[i] = false;
                        assert!(
                            wait_for(Duration::from_secs(10), || {
                                has_event(&flight, "site_recovered", SITES[i], t0)
                            }),
                            "site '{}' recovered with no site_recovered event",
                            SITES[i]
                        );
                    }
                }
                _ => std::thread::sleep(Duration::from_millis(100)),
            }
        }

        // Forced tail: home outage under load, then recovery. This is the
        // chain `supersonic explain` reconstructs; here we assert each
        // link's event exists against the public state that proves the
        // mutation happened.
        if down[0] {
            fed.recover_site(HOME);
            down[0] = false;
        }
        // The rebalancer must see home up (and hand its budget back)
        // before the kill, or the kill has no budget left to move; a
        // home-landing pick also re-arms the router's away latch so the
        // tail's failover is a fresh episode, not a deduped continuation.
        assert!(wait_for(Duration::from_secs(10), || {
            fed.running_by_site().get(HOME).copied().unwrap_or(0) > 0
        }));
        let home_before = fed.router.site_requests(HOME);
        assert!(
            wait_for(Duration::from_secs(10), || {
                burst(&addr, 5);
                fed.router.site_requests(HOME) > home_before
            }),
            "healthy home site never took traffic"
        );
        std::thread::sleep(Duration::from_millis(300));
        let t_kill = d.clock.now_secs();
        assert!(fed.fail_site(HOME));
        assert!(
            wait_for(Duration::from_secs(10), || {
                fed.running_by_site().get(HOME) == Some(&0)
            }),
            "home site never drained"
        );
        // Public state: the dead site's pods are gone -> ledger link.
        assert!(
            wait_for(Duration::from_secs(5), || has_event(&flight, "site_outage", HOME, t_kill)),
            "home drain left no site_outage event"
        );
        // Public state: remote sites serve while home is dead -> the
        // router must have recorded leaving the home site.
        let remote_before =
            fed.router.site_requests("nrp") + fed.router.site_requests("uchicago");
        burst(&addr, 40);
        let remote_after =
            fed.router.site_requests("nrp") + fed.router.site_requests("uchicago");
        if remote_after > remote_before {
            assert!(
                wait_for(Duration::from_secs(5), || {
                    flight.events().iter().any(|e| {
                        (e.kind == "failover" || e.kind == "spillover") && e.at >= t_kill
                    })
                }),
                "traffic left the dead home site with no failover/spillover event"
            );
        }
        // Public state: the rebalancer moved the dead site's budget to
        // the survivors (its budget gauge drops to the floor) -> every
        // budget move must be ledgered for its site.
        assert!(
            wait_for(Duration::from_secs(5), || {
                has_event(&flight, "budget_shift", HOME, t_kill)
            }),
            "home budget moved with no budget_shift event"
        );

        let t_back = d.clock.now_secs();
        assert!(fed.recover_site(HOME));
        assert!(
            wait_for(Duration::from_secs(10), || {
                fed.running_by_site().get(HOME).copied().unwrap_or(0) > 0
                    && has_event(&flight, "site_recovered", HOME, t_back)
            }),
            "home recovery left no site_recovered event"
        );
        // Repatriation: once home is warm and cheapest again, picks land
        // back on it and the router ledgers the return.
        assert!(
            wait_for(Duration::from_secs(10), || {
                burst(&addr, 10);
                has_event(&flight, "repatriation", HOME, t_back)
            }),
            "traffic repatriated with no repatriation event"
        );

        let events = flight.events();
        assert_ledger_well_formed(&events);
        assert!(
            events.len() <= d.cfg.observability.flight_recorder_capacity,
            "ring exceeded its configured capacity"
        );
        d.down();
    });
}
