//! Per-model autoscaling ablation — one global replica target vs one
//! target per model, at an EQUAL total-pod budget, under skewed
//! two-model traffic.
//!
//! Setup (see `experiments::per_model_autoscale_config`): two models
//! (particlenet hot, icecube_cnn cold) with a per-instance memory budget
//! that fits exactly one model, 90/10 request skew, autoscaler capped at
//! 6 pods in both arms. The global arm scales one desired count from
//! average queue latency — every new pod boots with the balanced
//! rotation placement, so only every other pod helps the hot model
//! (converging to 3 hot + 3 cold). The per-model arm runs one scaling
//! loop per model fed by placement demand; pods spawned for the hot
//! model boot advertising only it (converging to ~5 hot + 1 cold). With
//! the same pod budget, per-model scaling must serve strictly more
//! requests — per-model GPU allocation is the throughput lever (CMS
//! coprocessors-as-a-service, arXiv:2402.15366; Savard et al.,
//! arXiv:2312.06838).
//!
//! Run: `cargo bench --bench per_model_autoscale`
//! Smoke: `SUPERSONIC_SMOKE=1 cargo bench --bench per_model_autoscale`
//! (per-model arm only, compressed, liveness only)

use std::time::Duration;

use supersonic::deployment::Deployment;
use supersonic::experiments::{modelmesh_workload, per_model_autoscale_config};
use supersonic::util::bench::{smoke, Csv, Table};
use supersonic::workload::Schedule;

struct Row {
    label: String,
    ok: u64,
    shed: u64,
    errors: u64,
    hot_ok: u64,
    hot_shed: u64,
    cold_ok: u64,
    pods: usize,
    hot_replicas: usize,
    cold_replicas: usize,
    latency_ms: f64,
}

fn run_arm(per_model: bool, time_scale: f64) -> anyhow::Result<Row> {
    let cfg = per_model_autoscale_config(time_scale, per_model);
    let label = if per_model { "per-model" } else { "global" }.to_string();
    let budget = cfg.autoscaler.max_replicas;
    let d = Deployment::up(cfg)?;
    anyhow::ensure!(d.wait_ready(2, Duration::from_secs(60)), "fleet not ready");
    let pool = modelmesh_workload(&d.endpoint(), 0.9, d.clock.clone());
    let report = pool.run(&Schedule::constant(24, Duration::from_secs(60)));
    let router = d.router.as_ref().expect("mesh active").clone();
    let hot = report.per_model["particlenet"].clone();
    let cold = report.per_model["icecube_cnn"].clone();
    let pods = d.cluster.running();
    anyhow::ensure!(pods <= budget, "{label} arm exceeded the pod budget: {pods}");
    let row = Row {
        label,
        ok: report.total_ok(),
        shed: report.total_shed(),
        errors: report.total_errors(),
        hot_ok: hot.ok,
        hot_shed: hot.shed,
        cold_ok: cold.ok,
        pods,
        hot_replicas: router.replicas("particlenet"),
        cold_replicas: router.replicas("icecube_cnn"),
        latency_ms: report.overall_latency.mean() * 1e3,
    };
    d.down();
    Ok(row)
}

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    println!("== per-model autoscaling ablation: global vs per-model targets ==");
    if smoke() {
        let row = run_arm(true, 20.0)?;
        println!("(smoke) per-model arm: {} ok, {} pods", row.ok, row.pods);
        assert!(row.ok > 0, "per-model arm served nothing");
        return Ok(());
    }
    let time_scale = 8.0;
    println!(
        "budget 6 pods, 24 clients, 90/10 hot/cold skew, 60s clock run \
         (time_scale {time_scale}x)\n"
    );

    let global_row = run_arm(false, time_scale)?;
    eprintln!("global arm done ({} ok, {} pods)", global_row.ok, global_row.pods);
    let per_model_row = run_arm(true, time_scale)?;
    eprintln!(
        "per-model arm done ({} ok, {} pods)",
        per_model_row.ok, per_model_row.pods
    );

    let mut table = Table::new(&[
        "scaling", "ok", "shed", "err", "hot ok", "hot shed", "cold ok", "pods",
        "hot/cold replicas", "mean latency (ms)",
    ]);
    let mut csv = Csv::new(&[
        "scaling", "ok", "shed", "errors", "hot_ok", "hot_shed", "cold_ok", "pods",
        "hot_replicas", "cold_replicas", "mean_latency_ms",
    ]);
    for r in [&global_row, &per_model_row] {
        table.row(&[
            r.label.clone(),
            r.ok.to_string(),
            r.shed.to_string(),
            r.errors.to_string(),
            r.hot_ok.to_string(),
            r.hot_shed.to_string(),
            r.cold_ok.to_string(),
            r.pods.to_string(),
            format!("{}/{}", r.hot_replicas, r.cold_replicas),
            format!("{:.1}", r.latency_ms),
        ]);
        csv.row(&[
            r.label.clone(),
            r.ok.to_string(),
            r.shed.to_string(),
            r.errors.to_string(),
            r.hot_ok.to_string(),
            r.hot_shed.to_string(),
            r.cold_ok.to_string(),
            r.pods.to_string(),
            r.hot_replicas.to_string(),
            r.cold_replicas.to_string(),
            format!("{:.2}", r.latency_ms),
        ]);
    }
    println!("{}", table.render());
    let path = csv.save("per_model_autoscale")?;
    println!("CSV: {}", path.display());

    println!("\nchecks (equal pod budget, per-model targets win under skew):");
    println!(
        "  global   : {} ok, {} shed, {} pods, serving {}/{}",
        global_row.ok, global_row.shed, global_row.pods, global_row.hot_replicas,
        global_row.cold_replicas
    );
    println!(
        "  per-model: {} ok, {} shed, {} pods, serving {}/{}",
        per_model_row.ok, per_model_row.shed, per_model_row.pods,
        per_model_row.hot_replicas, per_model_row.cold_replicas
    );
    assert!(
        per_model_row.hot_replicas > global_row.hot_replicas,
        "per-model scaling never gave the hot model more replicas \
         (per-model {} vs global {})",
        per_model_row.hot_replicas,
        global_row.hot_replicas
    );
    assert!(
        per_model_row.ok > global_row.ok,
        "per-model scaling should serve strictly more requests at an equal \
         pod budget (per-model {} vs global {})",
        per_model_row.ok,
        global_row.ok
    );
    assert!(
        per_model_row.hot_shed < global_row.hot_shed,
        "per-model scaling should shed less hot-model traffic \
         (per-model {} vs global {})",
        per_model_row.hot_shed,
        global_row.hot_shed
    );
    Ok(())
}
