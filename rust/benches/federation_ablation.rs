//! Multi-site federation vs single cluster — the federation acceptance
//! bench.
//!
//! Two arms carrying the SAME skewed, bursty, mixed-priority traffic on
//! an equal total pod count:
//!
//! * **single-site** — one 6-pod cluster with per-model autoscaling (the
//!   pre-federation control plane). Healthy end to end: this arm is the
//!   no-WAN-overhead baseline.
//! * **federated** — three sites (2 pods each, gateway homed at the
//!   first) behind the federation router and the global budget
//!   rebalancer. Mid-run the WHOLE home site is killed
//!   ([`Federation::fail_site`]) and later recovered.
//!
//! Asserted on the federated arm: zero request errors and a bounded
//! critical-lane p99 across the entire run (service continues through
//! the outage on the surviving sites), spillover visible in the
//! per-site counters, and repatriation — the recovered home site takes
//! fresh traffic before the run ends.
//!
//! Run: `cargo bench --bench federation_ablation`
//! Smoke: `SUPERSONIC_SMOKE=1 cargo bench --bench federation_ablation`

use std::collections::BTreeMap;
use std::time::Duration;

use supersonic::config::*;
use supersonic::deployment::Deployment;
use supersonic::rpc::Priority;
use supersonic::util::bench::{smoke, Csv, Table};
use supersonic::workload::{MixEntry, MixedPool, Schedule, WorkloadSpec};

const TIME_SCALE: f64 = 8.0;
const HOME: &str = "purdue";
/// Whole-run critical p99 ceiling for the federated arm (clock seconds).
/// Critical service time is ~2.4 ms; the bound leaves room for burst
/// queueing and the WAN penalty but not for an outage-shaped stall.
const CRITICAL_P99_BOUND: f64 = 0.5;

fn models() -> Vec<ModelConfig> {
    vec![
        ModelConfig {
            name: "icecube_cnn".into(),
            max_queue_delay: Duration::from_millis(1),
            preferred_batch: 8,
            service_model: ServiceModelConfig {
                base: Duration::from_millis(2),
                per_row: Duration::from_micros(100),
            },
            ..ModelConfig::default()
        },
        ModelConfig {
            name: "particlenet".into(),
            max_queue_delay: Duration::from_millis(1),
            preferred_batch: 8,
            service_model: ServiceModelConfig {
                base: Duration::from_millis(2),
                per_row: Duration::from_micros(100),
            },
            ..ModelConfig::default()
        },
    ]
}

fn base_cfg(name: &str, replicas: usize) -> DeploymentConfig {
    DeploymentConfig {
        name: name.into(),
        server: ServerConfig {
            replicas,
            models: models(),
            repository: "artifacts".into(),
            startup_delay: Duration::from_millis(50),
            execution: ExecutionMode::Simulated,
            queue_capacity: 512,
            util_window: 10.0,
            batch_mode: Default::default(),
            priorities: Default::default(),
        },
        gateway: GatewayConfig::default(),
        autoscaler: AutoscalerConfig {
            enabled: true,
            min_replicas: 2,
            max_replicas: 12,
            poll_interval: Duration::from_millis(500),
            per_model: PerModelScalingConfig {
                enabled: true,
                threshold: 60.0,
                min_replicas: 1,
                max_replicas: 4,
            },
            ..AutoscalerConfig::default()
        },
        cluster: ClusterConfig {
            nodes: 4,
            gpus_per_node: 3,
            pod_start_delay: Duration::from_millis(50),
            termination_grace: Duration::from_millis(50),
            pod_failure_rate: 0.0,
        },
        federation: Default::default(),
        monitoring: MonitoringConfig {
            listen: String::new(),
            scrape_interval: Duration::from_secs(1),
            retention: Duration::from_secs(3600),
            tracing: false,
        },
        model_placement: ModelPlacementConfig {
            // Both models (~240 KB combined) fit on every pod: the arms
            // differ in topology, not in placement pressure.
            memory_budget_mb: 0.45,
            ..ModelPlacementConfig::default()
        },
        engines: Default::default(),
        observability: Default::default(),
        rpc: Default::default(),
        time_scale: TIME_SCALE,
    }
}

fn site(name: &str, wan: &[(&str, f64)]) -> SiteConfig {
    SiteConfig {
        name: name.into(),
        pod_budget: 4,
        replicas: 2,
        nodes: 2,
        gpus_per_node: 2,
        cpu_replicas: 0,
        wan: wan
            .iter()
            .map(|(p, s)| (p.to_string(), Duration::from_secs_f64(*s)))
            .collect::<BTreeMap<_, _>>(),
    }
}

fn federated_cfg(name: &str) -> DeploymentConfig {
    let mut cfg = base_cfg(name, 2);
    cfg.federation = FederationConfig {
        sites: vec![
            site(HOME, &[("nrp", 0.002), ("uchicago", 0.004)]),
            site("nrp", &[]),
            site("uchicago", &[]),
        ],
        gateway_site: HOME.into(),
        rebalance_interval: Duration::from_millis(500),
        spillover_queue_depth: 4.0,
    };
    cfg
}

/// Skewed mixed-priority traffic: a light critical lane and a heavy
/// (4x weight, 8x rows) bulk lane, 80/20 skewed toward the CNN.
fn mixed_entries() -> Vec<MixEntry> {
    vec![
        MixEntry {
            spec: WorkloadSpec::new("icecube_cnn", 1, vec![16, 16, 3])
                .with_priority(Priority::Critical),
            weight: 1.0,
        },
        MixEntry {
            spec: WorkloadSpec::new("icecube_cnn", 8, vec![16, 16, 3])
                .with_priority(Priority::Bulk),
            weight: 3.0,
        },
        MixEntry {
            spec: WorkloadSpec::new("particlenet", 4, vec![64, 7]),
            weight: 1.0,
        },
    ]
}

/// Bursty schedule: warm-up, a 3x client burst, then a long cool-down
/// (the outage + recovery window in the federated arm).
fn bursty() -> Schedule {
    Schedule::new()
        .phase(4, Duration::from_secs(8))
        .phase(12, Duration::from_secs(10))
        .phase(4, Duration::from_secs(22))
}

fn critical_p99(report: &supersonic::workload::MixedReport) -> f64 {
    report
        .per_entry
        .iter()
        .filter(|e| e.priority == Priority::Critical)
        .map(|e| e.latency.quantile(0.99))
        .fold(0.0, f64::max)
}

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    if smoke() {
        // Short continuity slice: boot the 3-site federation, kill the
        // home site under live traffic, recover it, and require
        // error-free service throughout. The spillover / p99 /
        // repatriation acceptance checks need the full run's timeline.
        println!("== federation ablation (smoke): outage continuity slice ==");
        let d = Deployment::up(federated_cfg("fed-smoke"))?;
        let fed = std::sync::Arc::clone(d.federation.as_ref().expect("federated deployment"));
        anyhow::ensure!(d.wait_ready(6, Duration::from_secs(30)), "federated fleet not ready");
        let pool = MixedPool::new(&d.endpoint(), mixed_entries(), d.clock.clone(), 11);
        let h =
            std::thread::spawn(move || pool.run(&Schedule::constant(4, Duration::from_secs(12))));
        d.clock.sleep(Duration::from_secs(4));
        anyhow::ensure!(fed.fail_site(HOME), "fail_site({HOME})");
        d.clock.sleep(Duration::from_secs(4));
        anyhow::ensure!(fed.recover_site(HOME), "recover_site({HOME})");
        let report = h.join().unwrap();
        d.down();
        println!("(smoke) {} ok, {} errors", report.total_ok(), report.total_errors());
        anyhow::ensure!(report.total_ok() > 0, "no requests served in smoke slice");
        anyhow::ensure!(report.total_errors() == 0, "errors across the smoke outage");
        return Ok(());
    }

    let mut table = Table::new(&[
        "arm", "ok", "shed", "errors", "critical p99 (s)", "spillover", "wan hops",
    ]);
    let mut csv = Csv::new(&[
        "arm", "ok", "shed", "errors", "critical_p99_s", "spillover", "wan_hops",
    ]);

    // ---- arm 1: single site, equal total pods, healthy ------------------
    println!("== single-site arm: 6 pods, no failure (baseline) ==");
    let d = Deployment::up(base_cfg("fed-single", 6))?;
    anyhow::ensure!(d.wait_ready(6, Duration::from_secs(30)), "single-site fleet not ready");
    let pool = MixedPool::new(&d.endpoint(), mixed_entries(), d.clock.clone(), 11);
    let report = pool.run(&bursty());
    let single_p99 = critical_p99(&report);
    println!(
        "single  : {} ok / {} shed / {} errors, critical p99 {:.4}s",
        report.total_ok(),
        report.total_shed(),
        report.total_errors(),
        single_p99
    );
    let cells = [
        "single-site".to_string(),
        report.total_ok().to_string(),
        report.total_shed().to_string(),
        report.total_errors().to_string(),
        format!("{single_p99:.4}"),
        "0".to_string(),
        "0".to_string(),
    ];
    table.row(&cells);
    csv.row(&cells);
    anyhow::ensure!(report.total_ok() > 0, "single-site arm served nothing");
    anyhow::ensure!(report.total_errors() == 0, "single-site arm errored");
    d.down();

    // ---- arm 2: 3-site federation, home-site outage mid-run -------------
    println!("\n== federated arm: 3 sites x 2 pods, home-site outage mid-burst ==");
    let d = Deployment::up(federated_cfg("fed-multi"))?;
    let fed = std::sync::Arc::clone(d.federation.as_ref().expect("federated deployment"));
    anyhow::ensure!(d.wait_ready(6, Duration::from_secs(30)), "federated fleet not ready");
    let pool = MixedPool::new(&d.endpoint(), mixed_entries(), d.clock.clone(), 11);
    let schedule = bursty();
    let h = std::thread::spawn(move || pool.run(&schedule));

    // Outage window: kill the home site early in the burst, recover it
    // at the start of the cool-down, leaving most of the last phase for
    // repatriated traffic.
    d.clock.sleep(Duration::from_secs(10));
    println!("-- failing site '{HOME}' mid-burst");
    anyhow::ensure!(fed.fail_site(HOME), "fail_site({HOME})");
    d.clock.sleep(Duration::from_secs(10));
    let home_before_recovery = fed.router.site_requests(HOME);
    println!("-- recovering site '{HOME}'");
    anyhow::ensure!(fed.recover_site(HOME), "recover_site({HOME})");

    let report = h.join().unwrap();
    let fed_p99 = critical_p99(&report);
    let spillover = fed.router.spillover_total();
    let home_after = fed.router.site_requests(HOME);
    let per_site: Vec<(String, u64)> = ["purdue", "nrp", "uchicago"]
        .iter()
        .map(|s| (s.to_string(), fed.router.site_requests(s)))
        .collect();
    let wan_hops: u64 = per_site
        .iter()
        .filter(|(s, _)| s != HOME)
        .map(|(_, n)| *n)
        .sum();
    d.down();

    println!(
        "federated: {} ok / {} shed / {} errors, critical p99 {:.4}s",
        report.total_ok(),
        report.total_shed(),
        report.total_errors(),
        fed_p99
    );
    for (s, n) in &per_site {
        println!("  site {s:<10} {n} requests");
    }
    println!(
        "  spillover {spillover}, home requests {home_before_recovery} at recovery -> {home_after} at end"
    );
    let cells = [
        "federated".to_string(),
        report.total_ok().to_string(),
        report.total_shed().to_string(),
        report.total_errors().to_string(),
        format!("{fed_p99:.4}"),
        spillover.to_string(),
        wan_hops.to_string(),
    ];
    table.row(&cells);
    csv.row(&cells);
    println!("\n{}", table.render());
    let path = csv.save("federation_ablation")?;
    println!("CSV: {}", path.display());

    anyhow::ensure!(report.total_ok() > 0, "federated arm served nothing");
    anyhow::ensure!(
        report.total_errors() == 0,
        "request errors during the site outage (service did not continue)"
    );
    anyhow::ensure!(
        fed_p99 < CRITICAL_P99_BOUND,
        "critical p99 {fed_p99:.4}s breached the {CRITICAL_P99_BOUND}s bound through the outage"
    );
    anyhow::ensure!(
        per_site.iter().all(|(_, n)| *n > 0),
        "every site must carry traffic across the run: {per_site:?}"
    );
    anyhow::ensure!(
        spillover > 0,
        "no spillover recorded: the burst never overflowed the cheapest site"
    );
    anyhow::ensure!(
        home_after > home_before_recovery,
        "no repatriation: home site took no traffic after recovery \
         ({home_before_recovery} -> {home_after})"
    );
    Ok(())
}
