//! End-to-end latency breakdown + SLO burn-rate alerting under an
//! overload/recovery cycle — the observability stack's acceptance bench.
//!
//! One traced deployment (2 simulated GPU servers, queue capacity far
//! above the offered load so overload shows up as queueing, not
//! shedding) runs three closed-loop phases:
//!
//!   steady (4 clients)  → overload (64 clients) → recovery (4 clients)
//!
//! Every request carries a wire-propagated trace id, so the gateway's
//! stage recorder accumulates `request_stage_seconds{stage=...}` from
//! real spans: gateway admit/ratelimit/route, batcher queue wait,
//! batch assembly and backend compute. The SLO engine evaluates the
//! per-model latency burn rate on its fast/slow windows throughout.
//!
//! Asserted:
//!   1. the per-stage sums reconstruct total request latency within 5%;
//!   2. queue time dominates compute during overload, compute dominates
//!      queue at steady state;
//!   3. the latency burn-rate alert fires during overload and resolves
//!      after recovery, with zero alert events during the steady phase;
//!   4. tracing-on throughput is within 5% of tracing-off at an equal
//!      budget (separate two-arm steady run).
//!
//! Run: `cargo bench --bench latency_breakdown`
//! Smoke: `SUPERSONIC_SMOKE=1 cargo bench --bench latency_breakdown`
//! (one short traced steady slice; stage-dominance and alert-lifecycle
//! assertions need the full overload/recovery cycle)

use std::time::Duration;

use supersonic::config::*;
use supersonic::deployment::Deployment;
use supersonic::metrics::registry::{labels, Registry};
use supersonic::telemetry::{slo, STAGES, STAGE_HISTOGRAM};
use supersonic::util::bench::{smoke, Csv, Table};
use supersonic::workload::{ClientPool, Schedule, WorkloadSpec};

const TIME_SCALE: f64 = 10.0;
const STEADY_CLIENTS: usize = 4;
const OVERLOAD_CLIENTS: usize = 64;
const PHASE: Duration = Duration::from_secs(30);
const ROWS: usize = 8;

fn bench_cfg(tracing: bool) -> DeploymentConfig {
    DeploymentConfig {
        name: if tracing { "trace-on".into() } else { "trace-off".into() },
        server: ServerConfig {
            replicas: 2,
            models: vec![ModelConfig {
                name: "particlenet".into(),
                max_queue_delay: Duration::from_millis(2),
                preferred_batch: 8,
                // 8 requests x 8 rows batched: ~101 ms per full batch,
                // so 64 closed-loop clients queue far past the 100 ms
                // p99 target while 4 clients stay well under it.
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(5),
                    per_row: Duration::from_micros(1500),
                },
                load_delay: None,
                backends: Vec::new(),
                ..ModelConfig::default()
            }],
            repository: "artifacts".into(),
            startup_delay: Duration::from_millis(100),
            execution: ExecutionMode::Simulated,
            queue_capacity: 512,
            util_window: 10.0,
            batch_mode: Default::default(),
            priorities: Default::default(),
        },
        gateway: GatewayConfig::default(),
        autoscaler: AutoscalerConfig {
            enabled: false,
            max_replicas: 2, // cluster capacity below
            ..AutoscalerConfig::default()
        },
        cluster: ClusterConfig {
            nodes: 1,
            gpus_per_node: 2,
            pod_start_delay: Duration::from_millis(50),
            termination_grace: Duration::from_millis(50),
            pod_failure_rate: 0.0,
        },
        monitoring: MonitoringConfig {
            listen: String::new(),
            scrape_interval: Duration::from_secs(1),
            retention: Duration::from_secs(3600),
            tracing,
        },
        model_placement: Default::default(),
        engines: Default::default(),
        observability: ObservabilityConfig {
            trace_sample_rate: 1.0,
            trace_capacity: 65536,
            slo_fast_window: Duration::from_secs(15),
            slo_slow_window: Duration::from_secs(40),
            slo_eval_interval: Duration::from_secs(2),
            slo_burn_threshold: 10.0,
            slos: vec![SloConfig {
                model: "particlenet".into(),
                latency_p99: Duration::from_millis(100),
                error_budget: 0.05,
            }],
            ..ObservabilityConfig::default()
        },
        rpc: Default::default(),
        federation: Default::default(),
        time_scale: TIME_SCALE,
    }
}

/// Sum of every `request_stage_seconds{stage=...}` histogram, by stage.
fn stage_sums(registry: &Registry) -> Vec<(&'static str, f64)> {
    STAGES
        .iter()
        .map(|&s| {
            (s, registry.histogram(STAGE_HISTOGRAM, &labels(&[("stage", s)])).snapshot().sum())
        })
        .collect()
}

fn sum_of(sums: &[(&'static str, f64)], stage: &str) -> f64 {
    sums.iter().find(|(s, _)| *s == stage).map(|(_, v)| *v).unwrap_or(0.0)
}

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    if smoke() {
        println!("== latency breakdown (smoke): one traced steady slice ==");
        let d = Deployment::up(bench_cfg(true))?;
        anyhow::ensure!(d.wait_ready(2, Duration::from_secs(30)), "fleet not ready");
        let spec = WorkloadSpec::new("particlenet", ROWS, vec![64, 7]).with_tracing();
        let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
        let report = pool.run(&Schedule::constant(STEADY_CLIENTS, Duration::from_secs(20)));
        let sums = stage_sums(&d.registry);
        let compute = sum_of(&sums, "compute");
        d.down();
        println!("(smoke) {} ok, compute stage sum {compute:.2}s", report.total_ok);
        assert!(report.total_ok > 0, "no requests served in smoke slice");
        assert!(compute > 0.0, "no compute spans recorded in smoke slice");
        return Ok(());
    }
    println!("== latency breakdown + SLO burn-rate alerting (overload/recovery) ==");
    println!(
        "2 servers, {STEADY_CLIENTS} -> {OVERLOAD_CLIENTS} -> {STEADY_CLIENTS} clients, \
         {}s clock per phase, p99 target 100 ms, burn threshold 10x \
         (time_scale {TIME_SCALE}x)\n",
        PHASE.as_secs()
    );

    // ---- main traced run: steady -> overload -> recovery ----------------
    let d = Deployment::up(bench_cfg(true))?;
    anyhow::ensure!(d.wait_ready(2, Duration::from_secs(30)), "fleet not ready");
    let slo_engine = d.slo.clone().expect("slo engine configured");

    let spec = WorkloadSpec::new("particlenet", ROWS, vec![64, 7]).with_tracing();
    let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
    let schedule = Schedule::new()
        .phase(STEADY_CLIENTS, PHASE)
        .phase(OVERLOAD_CLIENTS, PHASE)
        .phase(STEADY_CLIENTS, PHASE);

    // Per-phase stage-sum snapshots, taken at each phase boundary.
    let registry = d.registry.clone();
    let engine = std::sync::Arc::clone(&slo_engine);
    let mut snapshots: Vec<Vec<(&'static str, f64)>> = Vec::new();
    let mut events_at_boundary: Vec<usize> = Vec::new();
    let report = pool.run_with(&schedule, |i, c| {
        eprintln!("-- phase {i}: {c} client(s)");
        snapshots.push(stage_sums(&registry));
        events_at_boundary.push(engine.events().len());
    });
    snapshots.push(stage_sums(&d.registry));

    let total_hist = d.registry.histogram("request_total_seconds", &labels(&[])).snapshot();
    let dropped = d.tracer.dropped();
    let alert_log = slo_engine.render_log();
    let events = slo_engine.events();
    let resolved_at_end = !slo_engine.active("particlenet", "latency_burn_rate");
    d.down();

    // Per-phase deltas of the queue/compute stage sums.
    let delta = |phase: usize, stage: &str| {
        sum_of(&snapshots[phase + 1], stage) - sum_of(&snapshots[phase], stage)
    };
    let mut table = Table::new(&["phase", "clients", "ok", "queue (s)", "compute (s)", "p99 (s)"]);
    let mut csv = Csv::new(&["phase", "clients", "ok", "queue_s", "compute_s", "p99_s"]);
    for (i, p) in report.phases.iter().enumerate() {
        let cells = [
            ["steady", "overload", "recovery"][i].to_string(),
            p.clients.to_string(),
            p.ok.to_string(),
            format!("{:.2}", delta(i, "queue")),
            format!("{:.2}", delta(i, "compute")),
            format!("{:.4}", p.latency.quantile(0.99)),
        ];
        table.row(&cells);
        csv.row(&cells);
    }
    println!("{}", table.render());
    let path = csv.save("latency_breakdown")?;
    println!("CSV: {}", path.display());

    println!("\nalert log:\n{}", if alert_log.is_empty() { "(empty)" } else { &alert_log });
    println!("\nspans dropped: {dropped}");

    // 1. Per-stage sums reconstruct total request latency.
    let final_sums = snapshots.last().unwrap();
    let stages_total: f64 = final_sums.iter().map(|(_, v)| v).sum();
    let root_total = total_hist.sum();
    println!(
        "\nchecks:\n  stage reconstruction: sum(stages) {stages_total:.2}s vs \
         root {root_total:.2}s"
    );
    assert!(root_total > 0.0, "no traced requests recorded");
    assert!(
        (stages_total - root_total).abs() <= 0.05 * root_total,
        "stage sums ({stages_total:.2}s) do not reconstruct root latency \
         ({root_total:.2}s) within 5%"
    );

    // 2. Queue dominates under overload; compute dominates at steady state.
    println!(
        "  steady  : queue {:.2}s vs compute {:.2}s (compute must dominate)",
        delta(0, "queue"),
        delta(0, "compute")
    );
    println!(
        "  overload: queue {:.2}s vs compute {:.2}s (queue must dominate)",
        delta(1, "queue"),
        delta(1, "compute")
    );
    assert!(
        delta(0, "compute") > delta(0, "queue"),
        "compute should dominate queue at steady state"
    );
    assert!(
        delta(1, "queue") > delta(1, "compute"),
        "queue should dominate compute under overload"
    );

    // 3. Burn-rate alert: silent in steady, fires in overload, resolves.
    assert_eq!(
        events_at_boundary[1], 0,
        "false-positive alert events during the steady phase"
    );
    let latency_events: Vec<_> =
        events.iter().filter(|e| e.alert == "latency_burn_rate").collect();
    assert!(
        latency_events.iter().any(|e| e.kind == slo::AlertKind::Fired),
        "latency burn-rate alert never fired during overload"
    );
    assert!(
        latency_events.last().is_some_and(|e| e.kind == slo::AlertKind::Resolved),
        "latency burn-rate alert did not resolve after recovery"
    );
    assert!(resolved_at_end, "alert still active after recovery");
    println!(
        "  alerts: {} fired/resolved transition(s), none before overload",
        latency_events.len()
    );

    // ---- overhead arms: tracing on vs off at an equal budget ------------
    println!("\n== tracing overhead: on vs off, {STEADY_CLIENTS}x steady load ==");
    let mut throughput = Vec::new();
    for tracing in [false, true] {
        let d = Deployment::up(bench_cfg(tracing))?;
        anyhow::ensure!(d.wait_ready(2, Duration::from_secs(30)), "fleet not ready");
        let mut spec = WorkloadSpec::new("particlenet", ROWS, vec![64, 7]);
        spec.trace = tracing;
        let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
        let r = pool.run(&Schedule::constant(8, Duration::from_secs(20)));
        println!(
            "  tracing {}: {:.1} req/s ({} ok)",
            if tracing { "on " } else { "off" },
            r.throughput(),
            r.total_ok
        );
        throughput.push(r.throughput());
        d.down();
    }
    let ratio = throughput[1] / throughput[0];
    println!("  ratio on/off: {ratio:.3} (must be >= 0.95)");
    assert!(
        ratio >= 0.95,
        "tracing costs more than 5% throughput: on {:.1} vs off {:.1} req/s",
        throughput[1],
        throughput[0]
    );
    Ok(())
}
