//! §2.2 ablation — Envoy load-balancing algorithms.
//!
//! "Load balancing distributes incoming requests across multiple Triton
//! instances using predefined algorithms such as round robin."
//!
//! This ablation compares the gateway's four policies on a *heterogeneous*
//! pool — 6 instances, two of which are 3x slower (stragglers, e.g. a
//! shared or thermally-throttled GPU) — where policy choice actually
//! matters: round-robin keeps feeding the stragglers, least-connection
//! and utilization-aware route around them.
//!
//! Run: `cargo bench --bench lb_ablation`
//! Smoke: `SUPERSONIC_SMOKE=1 cargo bench --bench lb_ablation`
//! (one policy, shorter run, liveness only)

use std::sync::{Arc, RwLock};
use std::time::Duration;

use supersonic::config::{ExecutionMode, GatewayConfig, LbPolicy, ModelConfig, ServiceModelConfig};
use supersonic::gateway::Gateway;
use supersonic::metrics::Registry;
use supersonic::server::{Instance, ModelRepository};
use supersonic::telemetry::Tracer;
use supersonic::util::bench::{smoke, smoke_scaled, Csv, Table};
use supersonic::util::clock::Clock;
use supersonic::workload::{ClientPool, Schedule, WorkloadSpec};

fn instance(
    id: &str,
    repo: &Arc<ModelRepository>,
    clock: &Clock,
    registry: &Registry,
    per_row_us: u64,
) -> Arc<Instance> {
    let inst = Instance::start_with_mode(
        id,
        Arc::clone(repo),
        &[ModelConfig {
            name: "particlenet".into(),
            max_queue_delay: Duration::from_millis(2),
            preferred_batch: 16,
            service_model: ServiceModelConfig {
                base: Duration::from_millis(3),
                per_row: Duration::from_micros(per_row_us),
            },
            load_delay: None,
            backends: Vec::new(),
            ..ModelConfig::default()
        }],
        clock.clone(),
        registry.clone(),
        256,
        5.0,
        ExecutionMode::Simulated,
    );
    inst.mark_ready();
    inst
}

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    println!("== §2.2 ablation: load-balancing policies on a skewed pool ==");
    println!("pool: 4 fast instances (1.0x) + 2 stragglers (3.0x slower)\n");

    let repo = Arc::new(ModelRepository::load_metadata(
        std::path::Path::new("artifacts"),
        &["particlenet".into()],
    )?);

    let policies: Vec<LbPolicy> = if smoke() {
        vec![LbPolicy::LeastConnection]
    } else {
        vec![
            LbPolicy::RoundRobin,
            LbPolicy::Random,
            LbPolicy::LeastConnection,
            LbPolicy::UtilizationAware,
        ]
    };

    let mut table = Table::new(&[
        "policy", "ok", "req/s", "p50 ms", "p99 ms", "mean ms", "straggler share",
    ]);
    let mut csv = Csv::new(&["policy", "ok", "rps", "p50_ms", "p99_ms", "mean_ms", "straggler_share"]);

    for policy in policies {
        let clock = Clock::real();
        let registry = Registry::new();
        let mut instances: Vec<Arc<Instance>> = Vec::new();
        for i in 0..4 {
            instances.push(instance(&format!("fast-{i}"), &repo, &clock, &registry, 800));
        }
        for i in 0..2 {
            instances.push(instance(&format!("slow-{i}"), &repo, &clock, &registry, 2400));
        }
        let endpoints = Arc::new(RwLock::new(instances.clone()));
        let gateway = Gateway::start(
            &GatewayConfig { lb_policy: policy, ..GatewayConfig::default() },
            endpoints,
            clock.clone(),
            registry.clone(),
            Tracer::disabled(),
            None,
        )?;

        // 12 closed-loop clients, 15 s: enough offered load that routing
        // decisions dominate.
        let spec = WorkloadSpec::new("particlenet", 16, vec![64, 7]);
        let pool = ClientPool::new(&gateway.addr().to_string(), spec, clock.clone());
        let run_secs = smoke_scaled(15, 4) as u64;
        let report = pool.run(&Schedule::constant(12, Duration::from_secs(run_secs)));
        let p = &report.phases[0];
        anyhow::ensure!(p.ok > 0, "{} arm served nothing", policy.name());

        // How much traffic landed on the stragglers?
        let snapshot = registry.snapshot();
        let count_for = |prefix: &str| -> f64 {
            snapshot
                .iter()
                .filter(|s| s.name == "inference_requests_total" && s.id.contains(prefix))
                .map(|s| s.value.scalar())
                .sum()
        };
        let slow = count_for("slow-");
        let total = slow + count_for("fast-");
        let share = if total > 0.0 { slow / total } else { 0.0 };

        table.row(&[
            policy.name().to_string(),
            p.ok.to_string(),
            format!("{:.0}", p.throughput()),
            format!("{:.1}", p.latency.quantile(0.5) * 1e3),
            format!("{:.1}", p.latency.quantile(0.99) * 1e3),
            format!("{:.1}", p.latency.mean() * 1e3),
            format!("{:.0}%", share * 100.0),
        ]);
        csv.row(&[
            policy.name().to_string(),
            p.ok.to_string(),
            format!("{:.1}", p.throughput()),
            format!("{:.2}", p.latency.quantile(0.5) * 1e3),
            format!("{:.2}", p.latency.quantile(0.99) * 1e3),
            format!("{:.2}", p.latency.mean() * 1e3),
            format!("{:.4}", share),
        ]);

        gateway.shutdown();
        for i in instances {
            i.stop();
        }
        eprintln!("{} done", policy.name());
    }

    println!("{}", table.render());
    let path = csv.save("lb_ablation")?;
    println!("CSV: {}", path.display());
    println!(
        "\nexpectation: least_connection / utilization_aware shift traffic away from\n\
         stragglers (share < 2/6 = 33%) and cut tail latency vs round_robin/random."
    );
    Ok(())
}
