//! Fig. 2 — "Load-based autoscaling in SuperSONIC: the GPU server count
//! (orange) adjusts in response to spikes in latency (green) caused by
//! increased inference load (blue)."
//!
//! Regenerates the three series for the 1 → 10 → 1 client schedule and
//! prints them as aligned timelines plus an ASCII rendering; CSV is saved
//! under `bench_results/`.
//!
//! Run: `cargo bench --bench fig2_autoscaling`
//! Smoke: `SUPERSONIC_SMOKE=1 cargo bench --bench fig2_autoscaling`
//! (shorter, faster-dilated phases; liveness checks only — the
//! scale-up/recovery assertions need the full-length phases)

use std::time::Duration;

use supersonic::experiments::{fig_config, fig_workload, run_deployment};
use supersonic::util::bench::{ascii_chart, smoke, smoke_scaled, Csv, Table};
use supersonic::workload::Schedule;

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    println!("== Fig. 2: load-based autoscaling timeline ==");

    // 8x dilation, 240-second clock phases: ~95s wall. Smoke compresses
    // to 60-second phases at 24x (~8s wall).
    let time_scale = if smoke() { 24.0 } else { 8.0 };
    let phase = Duration::from_secs(smoke_scaled(240, 60) as u64);
    let cfg = fig_config(time_scale, None, phase);
    let schedule = Schedule::step_up_down(1, 10, phase);
    println!(
        "workload: 1 -> 10 -> 1 clients x {}s clock phases (time_scale {}x)\n",
        phase.as_secs(),
        time_scale
    );

    let result = run_deployment(cfg, fig_workload(), &schedule, Duration::from_secs(5))?;

    // Aligned table, one row per ~20 clock seconds.
    let mut table = Table::new(&["t (s)", "clients", "rate (rows/s)", "latency (s)", "servers"]);
    let t0 = result.rate.first().map(|&(t, _)| t).unwrap_or(0.0);
    for (i, &(t, rate)) in result.rate.iter().enumerate() {
        if i % 4 != 0 {
            continue;
        }
        let clients = schedule
            .clients_at(Duration::from_secs_f64((t - t0).max(0.0)))
            .unwrap_or(0);
        let latency = result.latency.get(i).map(|&(_, v)| v).unwrap_or(0.0);
        let servers = result.servers.get(i).map(|&(_, v)| v).unwrap_or(0.0);
        table.row(&[
            format!("{:.0}", t - t0),
            clients.to_string(),
            format!("{rate:.0}"),
            format!("{latency:.4}"),
            format!("{servers:.0}"),
        ]);
    }
    println!("{}", table.render());

    println!("{}", ascii_chart("inference rate (rows/s)", &result.rate, 90, 10));
    println!("{}", ascii_chart("avg queue latency (s)", &result.latency, 90, 10));
    println!("{}", ascii_chart("GPU servers", &result.servers, 90, 8));

    let mut csv = Csv::new(&["t", "rate_rows_per_s", "latency_s", "servers", "utilization"]);
    for (i, &(t, rate)) in result.rate.iter().enumerate() {
        csv.row(&[
            format!("{t:.1}"),
            format!("{rate:.1}"),
            format!("{:.5}", result.latency.get(i).map(|&(_, v)| v).unwrap_or(0.0)),
            format!("{:.0}", result.servers.get(i).map(|&(_, v)| v).unwrap_or(0.0)),
            format!("{:.4}", result.utilization.get(i).map(|&(_, v)| v).unwrap_or(0.0)),
        ]);
    }
    let path = csv.save("fig2_autoscaling")?;
    println!("series CSV: {}", path.display());

    // The paper's qualitative claims, asserted.
    let phase_s = phase.as_secs_f64();
    let lat_at = |lo: f64, hi: f64| -> f64 {
        let pts: Vec<f64> = result
            .latency
            .iter()
            .filter(|&&(t, _)| t - t0 >= lo && t - t0 < hi)
            .map(|&(_, v)| v)
            .collect();
        if pts.is_empty() { 0.0 } else { pts.iter().sum::<f64>() / pts.len() as f64 }
    };
    let spike = lat_at(phase_s, phase_s * 1.25);
    let settled = lat_at(phase_s * 1.7, phase_s * 2.0);
    println!("\nchecks:");
    println!("  peak servers:              {} (expect > 1, scale-up happened)", result.peak_servers);
    println!("  latency spike at step:     {spike:.3}s");
    println!("  latency after scale-up:    {settled:.3}s (expect < spike)");
    let final_servers = result.servers.last().map(|&(_, v)| v).unwrap_or(0.0);
    println!("  servers at end:            {final_servers:.0} (expect scale-down toward 1)");
    println!(
        "  phase summaries:           {}",
        result
            .report
            .phases
            .iter()
            .map(|p| format!("{}cl/{:.0}ok/{:.3}s", p.clients, p.ok, p.latency.mean()))
            .collect::<Vec<_>>()
            .join("  ")
    );

    let total_ok: u64 = result.report.phases.iter().map(|p| p.ok).sum();
    assert!(total_ok > 0, "no requests served");
    if smoke() {
        println!("(smoke: scale-up/recovery assertions skipped — phases too short)");
        return Ok(());
    }
    assert!(result.peak_servers > 1, "autoscaler never scaled up");
    assert!(spike > settled, "latency did not recover after scale-up");
    Ok(())
}
