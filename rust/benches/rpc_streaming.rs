//! Streaming multiplexed sessions vs request-per-exchange — the RPC
//! layer's acceptance bench.
//!
//! Phase A (throughput): one deployment (2 simulated replicas, demux
//! dispatch at the gateway), three client arms at an equal pod budget:
//!
//!   * `serial`    — one blocking [`RpcClient`], one request in flight
//!                   (the perf_analyzer model);
//!   * `reconnect` — a fresh TCP connection per request (the worst case
//!                   the session pool exists to avoid);
//!   * `pipelined` — ONE [`RpcSession`] holding a 64-deep window of
//!                   in-flight requests on a single connection.
//!
//! Asserted: the pipelined session sustains >= 5x the serial request
//! rate. The win is real concurrency, not a micro-artifact: a serial
//! connection is idle for a full round trip per request while the
//! batcher could be folding its requests into in-flight batches.
//!
//! Phase B (semantics): per-request metadata must survive multiplexing.
//! On one shared session carrying interleaved traffic through a gateway
//! with auth + a pressure gate + tracing enabled:
//!
//!   * a critical, authed, traced request lands Ok and its trace id
//!     accumulates real pipeline spans;
//!   * a bulk request is shed (`RateLimited`) by the priority-aware gate
//!     while the critical one on the SAME session passes;
//!   * a forged token comes back `Unauthorized`.
//!
//! Run: `cargo bench --bench rpc_streaming`
//! Smoke: `SUPERSONIC_SMOKE=1 cargo bench --bench rpc_streaming`

use std::collections::VecDeque;
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use supersonic::config::*;
use supersonic::deployment::Deployment;
use supersonic::gateway::ratelimit::PressureGate;
use supersonic::gateway::{auth, Gateway};
use supersonic::metrics::Registry;
use supersonic::rpc::codec::{InferRequest, RequestKind};
use supersonic::rpc::{Priority, RpcClient, RpcSession, SessionOpts, Status};
use supersonic::runtime::Tensor;
use supersonic::server::Instance;
use supersonic::telemetry::Tracer;
use supersonic::util::bench::{smoke, smoke_scaled, Csv, Table};
use supersonic::util::clock::Clock;

const WINDOW: usize = 64;
const ROWS: usize = 1;

fn bench_cfg() -> DeploymentConfig {
    DeploymentConfig {
        name: "rpc-streaming".into(),
        server: ServerConfig {
            replicas: 2,
            models: vec![ModelConfig {
                name: "icecube_cnn".into(),
                max_queue_delay: Duration::from_millis(1),
                preferred_batch: 8,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(2),
                    per_row: Duration::from_micros(100),
                },
                load_delay: None,
                backends: Vec::new(),
                ..ModelConfig::default()
            }],
            repository: "artifacts".into(),
            startup_delay: Duration::from_millis(10),
            execution: ExecutionMode::Simulated,
            queue_capacity: 512,
            util_window: 5.0,
            batch_mode: Default::default(),
            priorities: Default::default(),
        },
        gateway: GatewayConfig::default(),
        autoscaler: AutoscalerConfig { max_replicas: 2, ..Default::default() },
        cluster: ClusterConfig {
            nodes: 1,
            gpus_per_node: 2,
            pod_start_delay: Duration::from_millis(10),
            termination_grace: Duration::from_millis(50),
            pod_failure_rate: 0.0,
        },
        monitoring: MonitoringConfig {
            listen: String::new(),
            scrape_interval: Duration::from_secs(1),
            retention: Duration::from_secs(600),
            tracing: false,
        },
        model_placement: Default::default(),
        engines: Default::default(),
        observability: Default::default(),
        rpc: RpcConfig {
            dispatch_threads: WINDOW,
            max_inflight_per_conn: 2 * WINDOW,
            ..Default::default()
        },
        federation: Default::default(),
        time_scale: 1.0,
    }
}

fn input() -> Tensor {
    Tensor::zeros(vec![ROWS, 16, 16, 3])
}

/// Completed-ok count over `run` wall seconds, one blocking client.
fn arm_serial(endpoint: &str, run: Duration) -> usize {
    let mut client = RpcClient::connect(endpoint).unwrap();
    let deadline = Instant::now() + run;
    let mut ok = 0;
    while Instant::now() < deadline {
        if client.infer("icecube_cnn", input()).unwrap().status == Status::Ok {
            ok += 1;
        }
    }
    ok
}

/// One fresh connection per request — prices the dial the pool avoids.
fn arm_reconnect(endpoint: &str, run: Duration) -> usize {
    let deadline = Instant::now() + run;
    let mut ok = 0;
    while Instant::now() < deadline {
        let mut client = RpcClient::connect(endpoint).unwrap();
        if client.infer("icecube_cnn", input()).unwrap().status == Status::Ok {
            ok += 1;
        }
    }
    ok
}

/// One session, `WINDOW` requests in flight on one TCP connection.
fn arm_pipelined(endpoint: &str, run: Duration) -> usize {
    let session = RpcSession::connect(endpoint, SessionOpts::default()).unwrap();
    let deadline = Instant::now() + run;
    let mut window = VecDeque::new();
    let mut ok = 0;
    let req = InferRequest::infer(0, "icecube_cnn", input());
    while Instant::now() < deadline {
        if window.len() < WINDOW {
            window.push_back(session.submit(&req).unwrap());
        } else if window.pop_front().unwrap().wait().unwrap().status == Status::Ok {
            ok += 1;
        }
    }
    for reply in window {
        if reply.wait().unwrap().status == Status::Ok {
            ok += 1;
        }
    }
    ok
}

fn phase_a() -> anyhow::Result<()> {
    let run = Duration::from_secs(smoke_scaled(10, 2) as u64);
    println!(
        "== phase A: throughput at equal pod budget (2 simulated replicas, \
         {}s per arm{}) ==",
        run.as_secs(),
        if smoke() { ", smoke" } else { "" }
    );
    let d = Deployment::up(bench_cfg())?;
    anyhow::ensure!(d.wait_ready(2, Duration::from_secs(30)), "fleet not ready");
    let endpoint = d.endpoint();

    let serial = arm_serial(&endpoint, run);
    let reconnect = arm_reconnect(&endpoint, run);
    let pipelined = arm_pipelined(&endpoint, run);
    d.down();

    let rate = |n: usize| n as f64 / run.as_secs_f64();
    let mut table = Table::new(&["arm", "ok", "req/s", "vs serial"]);
    let mut csv = Csv::new(&["arm", "ok", "rps"]);
    for (name, n) in [("serial", serial), ("reconnect", reconnect), ("pipelined", pipelined)] {
        table.row(&[
            name.into(),
            n.to_string(),
            format!("{:.0}", rate(n)),
            format!("{:.1}x", n as f64 / serial as f64),
        ]);
        csv.row(&[name.into(), n.to_string(), format!("{:.1}", rate(n))]);
    }
    println!("{}", table.render());
    let path = csv.save("rpc_streaming")?;
    println!("CSV: {}", path.display());

    let speedup = pipelined as f64 / serial as f64;
    assert!(serial > 0, "serial arm completed nothing");
    assert!(
        speedup >= 5.0,
        "pipelined session only {speedup:.1}x the serial baseline \
         ({pipelined} vs {serial} ok in {}s) — want >= 5x",
        run.as_secs()
    );
    println!("pipelined speedup: {speedup:.1}x (>= 5x required)\n");
    Ok(())
}

fn phase_b() -> anyhow::Result<()> {
    println!("== phase B: metadata semantics on one multiplexed session ==");
    let clock = Clock::real();
    let registry = Registry::new();
    let tracer = Tracer::new(clock.clone(), 4096, true);
    let repo = Arc::new(
        supersonic::server::ModelRepository::load_metadata(
            std::path::Path::new("artifacts"),
            &["icecube_cnn".into()],
        )?,
    );
    let inst = Instance::start_with_mode(
        "rpc-bench-0",
        repo,
        &[ModelConfig {
            name: "icecube_cnn".into(),
            max_queue_delay: Duration::from_millis(1),
            preferred_batch: 8,
            service_model: ServiceModelConfig {
                base: Duration::from_millis(2),
                per_row: Duration::from_micros(100),
            },
            load_delay: None,
            backends: Vec::new(),
            ..ModelConfig::default()
        }],
        clock.clone(),
        registry.clone(),
        64,
        5.0,
        ExecutionMode::Simulated,
    );
    inst.mark_ready();
    let endpoints = Arc::new(RwLock::new(vec![Arc::clone(&inst)]));
    // Pressure pinned over the standard threshold: bulk and standard
    // shed, critical admits (2x factor). Auth secret set; demux on so
    // the session's interleaved requests execute concurrently.
    let secret = "bench-secret";
    let gate = PressureGate::new(Box::new(|| 1.0), 0.6);
    let gateway = Gateway::start_full(
        &GatewayConfig { auth_secret: Some(secret.into()), ..Default::default() },
        endpoints,
        clock,
        registry,
        tracer.clone(),
        Some(gate),
        None,
        PriorityConfig::default(),
        &RpcConfig { dispatch_threads: 8, ..Default::default() },
    )?;

    let session =
        RpcSession::connect(&gateway.addr().to_string(), SessionOpts::default()).unwrap();
    let token = auth::mint_token(secret);
    let trace_id = tracer.new_trace();
    let mk = |token: &str, priority: Priority, trace_id: u64| InferRequest {
        kind: RequestKind::Infer,
        request_id: 0, // the session stamps the wire id
        trace_id,
        sampled: trace_id != 0,
        token: token.to_string(),
        model: "icecube_cnn".into(),
        priority: Some(priority),
        input: input(),
    };

    // Interleave all three on the one session before awaiting anything.
    let critical = session.submit(&mk(&token, Priority::Critical, trace_id)).unwrap();
    let bulk = session.submit(&mk(&token, Priority::Bulk, 0)).unwrap();
    let forged = session.submit(&mk("deadbeef", Priority::Critical, 0)).unwrap();

    let r_critical = critical.wait()?;
    let r_bulk = bulk.wait()?;
    let r_forged = forged.wait()?;
    println!(
        "critical/authed/traced: {}   bulk: {}   forged token: {}",
        r_critical.status.name(),
        r_bulk.status.name(),
        r_forged.status.name()
    );
    assert_eq!(r_critical.status, Status::Ok, "{}", r_critical.error);
    assert_eq!(r_bulk.status, Status::RateLimited, "bulk not shed by the gate");
    assert_eq!(r_forged.status, Status::Unauthorized, "forged token admitted");

    let view = tracer.trace(trace_id);
    let names: Vec<&str> = view.spans.iter().map(|s| s.name.as_str()).collect();
    for stage in ["admit", "route", "compute"] {
        assert!(names.contains(&stage), "trace lost stage '{stage}' over the wire: {names:?}");
    }
    println!("trace {trace_id:#x} spans: {names:?}");
    println!("metadata preserved per in-flight request: OK\n");

    gateway.shutdown();
    inst.stop();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    phase_a()?;
    phase_b()
}
