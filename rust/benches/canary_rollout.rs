//! Model-version lifecycle under traffic — the canary/rollback
//! acceptance bench.
//!
//! Two arms over the same two-pod simulated fleet (CNN at v1 incumbent
//! + v2 canary splitting the bare name, GNN as cross-traffic):
//!
//! * **rolling upgrade** — the canary is healthy (v2 == v1 speed) and
//!   takes 25% of bare-name traffic; halfway through a mixed-priority
//!   closed-loop run the operator promotes it
//!   ([`Deployment::promote_canary`]), swapping the incumbent mid-flight.
//!   Asserted: zero errors (a `ModelNotFound` during the swap would land
//!   here) and zero sheds across the whole run, both versions actually
//!   served before the promote, and the rollback evaluator stayed quiet.
//!
//! * **poisoned canary** — v2 is 25x slower, so every request it serves
//!   costs >= 60 ms against the incumbent's ~5 ms. The auto-rollback
//!   evaluator (canary p99 vs incumbent p99 over the SLO fast/slow
//!   windows) must tear the split down on its own. Asserted: exactly one
//!   `model_version_rollback_total` fire with the `canary_auto_rollback`
//!   alert, zero errors, and a recovery-phase p99 *below the poisoned
//!   version's minimum service time* — proof the bare name is back on
//!   the incumbent within one slow window of the rollback.
//!
//! Run: `cargo bench --bench canary_rollout`
//! Smoke: `SUPERSONIC_SMOKE=1 cargo bench --bench canary_rollout`
//! (short healthy-upgrade slice; the poisoned arm needs the full
//! windowed run)

use std::time::Duration;

use supersonic::config::*;
use supersonic::deployment::Deployment;
use supersonic::metrics::registry::labels;
use supersonic::rpc::Priority;
use supersonic::telemetry::rollback::{ROLLBACK_ALERT, ROLLBACK_COUNTER, VERSION_REQUESTS_COUNTER};
use supersonic::util::bench::{smoke, Csv, Table};
use supersonic::workload::{ClientPool, MixEntry, MixedPool, Schedule, WorkloadSpec};

const TIME_SCALE: f64 = 10.0;
const ROWS: usize = 4;
const CLIENTS: usize = 12;
const PHASE: Duration = Duration::from_secs(20);
/// Poisoned-canary service-time multiplier. Any request the poisoned
/// version serves takes at least `POISON_SLOWDOWN x (base + rows x
/// per_row)` = 25 x 2.4 ms = 60 ms, so a recovery-phase p99 below
/// [`POISON_FLOOR`] proves the canary is out of the serving path.
const POISON_SLOWDOWN: f64 = 25.0;
const POISON_FLOOR: f64 = POISON_SLOWDOWN * (0.002 + ROWS as f64 * 0.0001);

fn bench_cfg(name: &str, canary_slowdown: f64, weight: f64) -> DeploymentConfig {
    let cnn_service = ServiceModelConfig {
        base: Duration::from_millis(2),
        per_row: Duration::from_micros(100),
    };
    DeploymentConfig {
        name: name.into(),
        server: ServerConfig {
            replicas: 2,
            models: vec![
                ModelConfig {
                    name: "icecube_cnn".into(),
                    max_queue_delay: Duration::from_millis(1),
                    preferred_batch: 8,
                    service_model: cnn_service,
                    versions: vec![
                        VersionSpec { version: 1, slowdown: 1.0 },
                        VersionSpec { version: 2, slowdown: canary_slowdown },
                    ],
                    incumbent: Some(1),
                    canary: Some(CanaryConfig { version: 2, weight, ..CanaryConfig::default() }),
                    ..ModelConfig::default()
                },
                ModelConfig {
                    name: "particlenet".into(),
                    max_queue_delay: Duration::from_millis(1),
                    preferred_batch: 8,
                    service_model: ServiceModelConfig {
                        base: Duration::from_millis(2),
                        per_row: Duration::from_micros(100),
                    },
                    ..ModelConfig::default()
                },
            ],
            repository: "artifacts".into(),
            startup_delay: Duration::from_millis(50),
            execution: ExecutionMode::Simulated,
            queue_capacity: 512,
            util_window: 10.0,
            batch_mode: Default::default(),
            priorities: Default::default(),
        },
        gateway: GatewayConfig::default(),
        autoscaler: AutoscalerConfig {
            enabled: false,
            max_replicas: 2,
            ..AutoscalerConfig::default()
        },
        cluster: ClusterConfig {
            nodes: 1,
            gpus_per_node: 2,
            pod_start_delay: Duration::from_millis(50),
            termination_grace: Duration::from_millis(50),
            pod_failure_rate: 0.0,
        },
        monitoring: MonitoringConfig {
            listen: String::new(),
            scrape_interval: Duration::from_secs(1),
            retention: Duration::from_secs(3600),
            tracing: false,
        },
        model_placement: ModelPlacementConfig {
            // Both CNN versions (~152 KB each) plus the GNN (~87 KB) fit
            // on every pod: the rollout is routing-, not placement-bound.
            memory_budget_mb: 0.45,
            ..ModelPlacementConfig::default()
        },
        engines: Default::default(),
        observability: ObservabilityConfig {
            slo_fast_window: Duration::from_secs(8),
            slo_slow_window: Duration::from_secs(20),
            slo_eval_interval: Duration::from_secs(1),
            rollback_latency_factor: 2.0,
            rollback_error_margin: 0.05,
            rollback_min_requests: 20,
            ..ObservabilityConfig::default()
        },
        rpc: Default::default(),
        federation: Default::default(),
        time_scale: TIME_SCALE,
    }
}

/// Mixed-priority closed-loop traffic: critical + bulk lanes on the
/// versioned CNN (via its bare name) and a standard GNN cross-stream.
fn mixed_entries() -> Vec<MixEntry> {
    vec![
        MixEntry {
            spec: WorkloadSpec::new("icecube_cnn", ROWS, vec![16, 16, 3])
                .with_priority(Priority::Critical),
            weight: 2.0,
        },
        MixEntry {
            spec: WorkloadSpec::new("icecube_cnn", ROWS, vec![16, 16, 3])
                .with_priority(Priority::Bulk),
            weight: 2.0,
        },
        MixEntry {
            spec: WorkloadSpec::new("particlenet", ROWS, vec![64, 7]),
            weight: 1.0,
        },
    ]
}

fn version_requests(d: &Deployment, version: &str) -> u64 {
    d.registry
        .counter(VERSION_REQUESTS_COUNTER, &labels(&[("model", "icecube_cnn"), ("version", version)]))
        .get()
}

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    if smoke() {
        println!("== canary rollout (smoke): short healthy upgrade slice ==");
        let d = Deployment::up(bench_cfg("canary-smoke", 1.0, 0.25))?;
        anyhow::ensure!(d.wait_ready(2, Duration::from_secs(30)), "fleet not ready");
        let pool = MixedPool::new(&d.endpoint(), mixed_entries(), d.clock.clone(), 7);
        let h = std::thread::spawn(move || pool.run(&Schedule::constant(4, Duration::from_secs(10))));
        d.clock.sleep(Duration::from_secs(5));
        anyhow::ensure!(d.promote_canary("icecube_cnn"), "promote failed");
        let report = h.join().unwrap();
        d.down();
        println!("(smoke) {} ok, {} errors", report.total_ok(), report.total_errors());
        assert!(report.total_ok() > 0, "no requests served in smoke slice");
        assert_eq!(report.total_errors(), 0, "errors during smoke upgrade");
        return Ok(());
    }

    println!("== canary rollout: rolling upgrade + poisoned-canary auto-rollback ==");
    println!(
        "2 pods, {CLIENTS} mixed-priority clients, {}s clock per phase, \
         rollback windows 8s/20s (time_scale {TIME_SCALE}x)\n",
        PHASE.as_secs()
    );
    let mut table =
        Table::new(&["arm", "ok", "shed", "errors", "p99 early (s)", "p99 late (s)", "rollbacks"]);
    let mut csv = Csv::new(&["arm", "ok", "shed", "errors", "p99_early_s", "p99_late_s", "rollbacks"]);

    // ---- arm 1: healthy canary, promoted mid-traffic --------------------
    let d = Deployment::up(bench_cfg("canary-upgrade", 1.0, 0.25))?;
    anyhow::ensure!(d.wait_ready(2, Duration::from_secs(30)), "fleet not ready");
    let rollback = d.rollback.clone().expect("canary config arms the rollback engine");
    let pool = MixedPool::new(&d.endpoint(), mixed_entries(), d.clock.clone(), 7);
    let half = PHASE;
    let h = std::thread::spawn(move || {
        pool.run(&Schedule::constant(CLIENTS, 2 * PHASE))
    });
    d.clock.sleep(half);
    let v1_before = version_requests(&d, "v1");
    let v2_before = version_requests(&d, "v2");
    anyhow::ensure!(d.promote_canary("icecube_cnn"), "promote_canary failed mid-traffic");
    let report = h.join().unwrap();

    let router = d.router.clone().expect("mesh router");
    let promoted_incumbent = d.repository.incumbent("icecube_cnn");
    let split_after = router.canary_of("icecube_cnn");
    let rollbacks_1 =
        d.registry.counter(ROLLBACK_COUNTER, &labels(&[("model", "icecube_cnn")])).get();
    let quiet = !rollback.rolled_back("icecube_cnn") && rollback.events().is_empty();
    d.down();

    let cnn = &report.per_model["icecube_cnn"];
    println!(
        "upgrade : {} ok / {} shed / {} errors; v1 {} + v2 {} requests before promote",
        report.total_ok(),
        report.total_shed(),
        report.total_errors(),
        v1_before,
        v2_before
    );
    for e in &report.per_entry {
        println!(
            "  {:<14} {:?}: {} ok, p99 {:.4}s",
            e.model,
            e.priority,
            e.ok,
            e.latency.quantile(0.99)
        );
    }
    let cells = [
        "upgrade".to_string(),
        report.total_ok().to_string(),
        report.total_shed().to_string(),
        report.total_errors().to_string(),
        format!("{:.4}", report.overall_latency.quantile(0.99)),
        format!("{:.4}", report.overall_latency.quantile(0.99)),
        rollbacks_1.to_string(),
    ];
    table.row(&cells);
    csv.row(&cells);

    assert!(report.total_ok() > 0 && cnn.ok > 0, "no CNN traffic served");
    assert_eq!(
        report.total_errors(),
        0,
        "errors (ModelNotFound would land here) during the rolling upgrade"
    );
    assert_eq!(report.total_shed(), 0, "shed spike during the rolling upgrade");
    assert!(
        v1_before > 0 && v2_before > 0,
        "canary split must exercise both versions before the promote \
         (v1 {v1_before}, v2 {v2_before})"
    );
    assert_eq!(promoted_incumbent, Some(2), "promotion must advance the incumbent");
    assert!(split_after.is_none(), "promotion must tear the split down");
    assert_eq!(rollbacks_1, 0, "healthy canary must not auto-roll back");
    assert!(quiet, "rollback evaluator fired on a healthy canary");

    // ---- arm 2: poisoned canary, auto-rollback --------------------------
    println!("\n== poisoned canary: v2 at {POISON_SLOWDOWN}x service time, 30% split ==");
    let d = Deployment::up(bench_cfg("canary-poisoned", POISON_SLOWDOWN, 0.3))?;
    anyhow::ensure!(d.wait_ready(2, Duration::from_secs(30)), "fleet not ready");
    let rollback = d.rollback.clone().expect("canary config arms the rollback engine");

    let spec = WorkloadSpec::new("icecube_cnn", ROWS, vec![16, 16, 3]);
    let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
    // Poison phase long enough for both burn windows to fill and fire;
    // recovery phase is exactly one slow window.
    let schedule = Schedule::new()
        .phase(8, PHASE + Duration::from_secs(5))
        .phase(8, d.cfg.observability.slo_slow_window);
    let report = pool.run_with(&schedule, |i, c| eprintln!("-- phase {i}: {c} client(s)"));

    let rollbacks_2 =
        d.registry.counter(ROLLBACK_COUNTER, &labels(&[("model", "icecube_cnn")])).get();
    let rolled = rollback.rolled_back("icecube_cnn");
    let events = rollback.events();
    let split_after = d.router.as_ref().unwrap().canary_of("icecube_cnn");
    let alert_log = rollback.render_log();
    d.down();

    let p99_poison = report.phases[0].latency.quantile(0.99);
    let p99_recovery = report.phases[1].latency.quantile(0.99);
    println!(
        "poisoned: {} ok / {} errors; p99 poison {:.4}s -> recovery {:.4}s \
         (floor {POISON_FLOOR:.3}s); {} rollback(s)",
        report.total_ok, report.total_errors, p99_poison, p99_recovery, rollbacks_2
    );
    println!("alert log:\n{}", if alert_log.is_empty() { "(empty)" } else { &alert_log });
    let cells = [
        "poisoned".to_string(),
        report.total_ok.to_string(),
        report.total_shed.to_string(),
        report.total_errors.to_string(),
        format!("{p99_poison:.4}"),
        format!("{p99_recovery:.4}"),
        rollbacks_2.to_string(),
    ];
    table.row(&cells);
    csv.row(&cells);
    println!("\n{}", table.render());
    let path = csv.save("canary_rollout")?;
    println!("CSV: {}", path.display());

    assert!(rolled, "poisoned canary never auto-rolled back");
    assert_eq!(rollbacks_2, 1, "exactly one rollback must fire");
    assert_eq!(events.len(), 1, "exactly one rollback event expected");
    assert_eq!(events[0].alert, ROLLBACK_ALERT);
    assert!(split_after.is_none(), "rollback must clear the canary split");
    assert_eq!(report.total_errors, 0, "rollback must not surface request errors");
    // Every poisoned-version request costs >= POISON_FLOOR of service
    // time alone, so a recovery p99 below it means <1% of the recovery
    // phase touched v2: the incumbent is back within one slow window.
    assert!(
        p99_recovery < POISON_FLOOR,
        "recovery p99 {p99_recovery:.4}s not below the poisoned floor \
         {POISON_FLOOR:.3}s: incumbent not restored within one slow window"
    );
    assert!(
        p99_poison > p99_recovery,
        "poison-phase p99 ({p99_poison:.4}s) should exceed recovery p99 ({p99_recovery:.4}s)"
    );
    Ok(())
}
