//! Modelmesh ablation — static all-models-under-budget placement vs
//! dynamic demand-driven placement, under skewed two-model traffic.
//!
//! Setup (see `experiments::modelmesh_config`): four simulated GPU
//! servers whose memory budget fits exactly one model, two models
//! (particlenet hot, icecube_cnn cold), 90/10 request skew. The static
//! arm keeps the boot-time balanced partition (2 hot + 2 cold replicas);
//! the dynamic arm lets the placement controller move replicas toward
//! demand (expected convergence: 3 hot + 1 cold). With the same instance
//! count, dynamic placement must serve strictly more requests and shed
//! fewer — per-model server allocation is the throughput lever (Savard
//! et al., arXiv:2312.06838).
//!
//! Run: `cargo bench --bench modelmesh_ablation`
//! Smoke: `SUPERSONIC_SMOKE=1 cargo bench --bench modelmesh_ablation`
//! (dynamic arm only, compressed, liveness only)

use std::time::Duration;

use supersonic::config::PlacementPolicy;
use supersonic::deployment::Deployment;
use supersonic::experiments::{modelmesh_config, modelmesh_workload};
use supersonic::util::bench::{smoke, Csv, Table};
use supersonic::workload::Schedule;

struct Row {
    label: String,
    ok: u64,
    shed: u64,
    errors: u64,
    hot_ok: u64,
    hot_shed: u64,
    cold_ok: u64,
    hot_replicas: usize,
    cold_replicas: usize,
    latency_ms: f64,
}

fn run_arm(policy: PlacementPolicy, time_scale: f64) -> anyhow::Result<Row> {
    let cfg = modelmesh_config(time_scale, policy);
    let label = cfg.model_placement.policy.name().to_string();
    let d = Deployment::up(cfg)?;
    anyhow::ensure!(d.wait_ready(4, Duration::from_secs(60)), "fleet not ready");
    let pool = modelmesh_workload(&d.endpoint(), 0.9, d.clock.clone());
    let report = pool.run(&Schedule::constant(16, Duration::from_secs(60)));
    let router = d.router.as_ref().expect("mesh active").clone();
    let hot = report.per_model["particlenet"].clone();
    let cold = report.per_model["icecube_cnn"].clone();
    let row = Row {
        label,
        ok: report.total_ok(),
        shed: report.total_shed(),
        errors: report.total_errors(),
        hot_ok: hot.ok,
        hot_shed: hot.shed,
        cold_ok: cold.ok,
        hot_replicas: router.replicas("particlenet"),
        cold_replicas: router.replicas("icecube_cnn"),
        latency_ms: report.overall_latency.mean() * 1e3,
    };
    d.down();
    Ok(row)
}

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    println!("== modelmesh ablation: static vs dynamic model placement ==");
    if smoke() {
        let row = run_arm(PlacementPolicy::Dynamic, 20.0)?;
        println!("(smoke) dynamic arm: {} ok, {} shed", row.ok, row.shed);
        assert!(row.ok > 0, "dynamic arm served nothing");
        return Ok(());
    }
    let time_scale = 8.0;
    println!(
        "4 instances, budget fits 1 model each, 16 clients, 90/10 hot/cold skew, \
         60s clock run (time_scale {time_scale}x)\n"
    );

    let static_row = run_arm(PlacementPolicy::Static, time_scale)?;
    eprintln!("static arm done ({} ok)", static_row.ok);
    let dynamic_row = run_arm(PlacementPolicy::Dynamic, time_scale)?;
    eprintln!("dynamic arm done ({} ok)", dynamic_row.ok);

    let mut table = Table::new(&[
        "policy", "ok", "shed", "err", "hot ok", "hot shed", "cold ok",
        "hot/cold replicas", "mean latency (ms)",
    ]);
    let mut csv = Csv::new(&[
        "policy", "ok", "shed", "errors", "hot_ok", "hot_shed", "cold_ok",
        "hot_replicas", "cold_replicas", "mean_latency_ms",
    ]);
    for r in [&static_row, &dynamic_row] {
        table.row(&[
            r.label.clone(),
            r.ok.to_string(),
            r.shed.to_string(),
            r.errors.to_string(),
            r.hot_ok.to_string(),
            r.hot_shed.to_string(),
            r.cold_ok.to_string(),
            format!("{}/{}", r.hot_replicas, r.cold_replicas),
            format!("{:.1}", r.latency_ms),
        ]);
        csv.row(&[
            r.label.clone(),
            r.ok.to_string(),
            r.shed.to_string(),
            r.errors.to_string(),
            r.hot_ok.to_string(),
            r.hot_shed.to_string(),
            r.cold_ok.to_string(),
            r.hot_replicas.to_string(),
            r.cold_replicas.to_string(),
            format!("{:.2}", r.latency_ms),
        ]);
    }
    println!("{}", table.render());
    let path = csv.save("modelmesh_ablation")?;
    println!("CSV: {}", path.display());

    println!("\nchecks (same fleet, demand-driven placement wins under skew):");
    println!(
        "  static : {} ok, {} shed, placement {}/{}",
        static_row.ok, static_row.shed, static_row.hot_replicas, static_row.cold_replicas
    );
    println!(
        "  dynamic: {} ok, {} shed, placement {}/{}",
        dynamic_row.ok, dynamic_row.shed, dynamic_row.hot_replicas, dynamic_row.cold_replicas
    );
    assert!(
        dynamic_row.hot_replicas > static_row.hot_replicas,
        "dynamic placement never reallocated replicas to the hot model"
    );
    assert!(
        dynamic_row.ok > static_row.ok,
        "dynamic placement should serve strictly more requests \
         (dynamic {} vs static {})",
        dynamic_row.ok,
        static_row.ok
    );
    assert!(
        dynamic_row.hot_shed < static_row.hot_shed,
        "dynamic placement should shed less hot-model traffic \
         (dynamic {} vs static {})",
        dynamic_row.hot_shed,
        static_row.hot_shed
    );
    Ok(())
}
