//! §2.2 overhead — what the proxy costs on the request path.
//!
//! The gateway adds auth, rate limiting, load balancing and a TCP hop in
//! front of the inference server. The paper's design assumes this
//! overhead is negligible relative to model compute; this bench measures
//! it directly, layer by layer, using the real PJRT-compiled CNN:
//!
//!   1. direct     — submit to the instance in-process (no network)
//!   2. rpc        — through the gateway over loopback TCP
//!   3. rpc+auth   — plus HMAC token verification
//!   4. rpc+auth+rl— plus token-bucket rate limiting (uncontended)
//!
//! Run: `cargo bench --bench gateway_overhead`
//! Smoke: `SUPERSONIC_SMOKE=1 cargo bench --bench gateway_overhead`
//! (simulated execution instead of PJRT, a handful of iterations —
//! exercises every layer, asserts liveness not overhead fractions)

use std::sync::{Arc, RwLock};
use std::time::Duration;

use supersonic::config::{ExecutionMode, GatewayConfig, ModelConfig};
use supersonic::gateway::{auth, Gateway};
use supersonic::metrics::Registry;
use supersonic::rpc::client::RpcClient;
use supersonic::rpc::codec::Status;
use supersonic::runtime::{PjrtRuntime, Tensor};
use supersonic::server::{Instance, ModelRepository};
use supersonic::telemetry::Tracer;
use supersonic::util::bench::{smoke, smoke_scaled, Bencher, Table};
use supersonic::util::clock::Clock;

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    println!("== §2.2: gateway overhead on the request path ==\n");

    // Smoke mode runs without the PJRT native library (absent in CI):
    // metadata-only repository + simulated execution keep the whole
    // gateway/auth/ratelimit path identical while compute is a sleep.
    let (repo, exec_mode) = if smoke() {
        let repo = Arc::new(ModelRepository::load_metadata(
            std::path::Path::new("artifacts"),
            &["icecube_cnn".into()],
        )?);
        (repo, ExecutionMode::Simulated)
    } else {
        let runtime = PjrtRuntime::cpu()?;
        let repo = Arc::new(ModelRepository::load(
            &runtime,
            std::path::Path::new("artifacts"),
            &["icecube_cnn".into()],
        )?);
        (repo, ExecutionMode::Real)
    };
    let clock = Clock::real();
    let registry = Registry::new();
    let inst = Instance::start_with_mode(
        "ov-0",
        Arc::clone(&repo),
        &[ModelConfig {
            name: "icecube_cnn".into(),
            max_queue_delay: Duration::ZERO, // isolate per-request cost
            preferred_batch: 1,
            ..ModelConfig::default()
        }],
        clock.clone(),
        registry.clone(),
        256,
        5.0,
        exec_mode,
    );
    inst.mark_ready();
    let input = Tensor::zeros(vec![1, 16, 16, 3]);

    let bencher = Bencher::new(smoke_scaled(50, 5), smoke_scaled(400, 50));
    let mut table = Table::new(&["path", "mean", "p50", "p99", "overhead vs direct"]);
    let mut results = Vec::new();

    // 1. direct
    let r_direct = bencher.run("direct", || {
        let out = inst.submit_and_wait("icecube_cnn", input.clone(), 0);
        assert!(matches!(out, supersonic::server::batcher::ExecOutcome::Ok { .. }));
    });
    results.push(("direct (in-process)", r_direct.clone(), None));

    // Helper to bench one gateway configuration.
    let mut bench_gateway = |label: &'static str, cfg: GatewayConfig, token: String| {
        let endpoints = Arc::new(RwLock::new(vec![Arc::clone(&inst)]));
        let gateway = Gateway::start(
            &cfg,
            endpoints,
            clock.clone(),
            registry.clone(),
            Tracer::disabled(),
            None,
        )
        .unwrap();
        let mut client = RpcClient::connect(&gateway.addr().to_string())
            .unwrap()
            .with_token(&token);
        let result = bencher.run(label, || {
            let resp = client.infer("icecube_cnn", input.clone()).unwrap();
            assert_eq!(resp.status, Status::Ok, "{}", resp.error);
        });
        gateway.shutdown();
        result
    };

    // 2. plain RPC
    let r_rpc = bench_gateway("rpc", GatewayConfig::default(), String::new());
    results.push(("gateway (loopback TCP)", r_rpc, Some(&r_direct)));

    // 3. + auth
    let secret = "bench-secret".to_string();
    let r_auth = bench_gateway(
        "rpc+auth",
        GatewayConfig { auth_secret: Some(secret.clone()), ..GatewayConfig::default() },
        auth::mint_token(&secret),
    );
    results.push(("gateway + auth", r_auth, Some(&r_direct)));

    // 4. + rate limit (high limit: measure mechanism, not shedding)
    let r_rl = bench_gateway(
        "rpc+auth+ratelimit",
        GatewayConfig {
            auth_secret: Some(secret.clone()),
            rate_limit_rps: 1e6,
            rate_limit_burst: 1024,
            ..GatewayConfig::default()
        },
        auth::mint_token(&secret),
    );
    results.push(("gateway + auth + rate limit", r_rl, Some(&r_direct)));

    for (label, r, baseline) in &results {
        let overhead = baseline
            .map(|b| format!("+{:.0} us", (r.mean_s - b.mean_s) * 1e6))
            .unwrap_or_else(|| "-".into());
        table.row(&[
            label.to_string(),
            format!("{:.3} ms", r.mean_s * 1e3),
            format!("{:.3} ms", r.p50_s * 1e3),
            format!("{:.3} ms", r.p99_s * 1e3),
            overhead,
        ]);
    }
    println!("{}", table.render());

    let direct_mean = results[0].1.mean_s;
    let full_mean = results[3].1.mean_s;
    let overhead_frac = (full_mean - direct_mean) / direct_mean;
    println!(
        "full gateway pipeline adds {:.0} us ({:.0}% of the {:.2} ms compute) per request",
        (full_mean - direct_mean) * 1e6,
        overhead_frac * 100.0,
        direct_mean * 1e3,
    );
    println!("paper's assumption holds if the proxy is a small fraction of compute.");

    inst.stop();
    Ok(())
}
