//! Warm-load ablation — instant vs costed model loads × naive (fifo) vs
//! model-affinity batching, at an EQUAL pod budget under skewed
//! two-model traffic with a mid-run demand flip.
//!
//! Setup (see `experiments::warm_load_config`): four simulated GPU
//! servers whose memory budget fits BOTH models, dynamic placement, and
//! a cold model whose batching window is wide and rarely filled. Phase A
//! runs 90/10 hot/cold; phase B flips the skew to 10/90, forcing the
//! placement controller to migrate replicas toward the new hot model —
//! and, in the costed arms, to pay a real `Loading` window (pool
//! exclusion + discounted move scoring) for every load.
//!
//! What the arms show:
//!
//! * **instant vs costed** — with free loads the ablation overstates
//!   dynamic placement's benefit: the instant arms adapt to the flip at
//!   zero price, while the costed arms lose the load windows and
//!   suppress marginal moves (the honest number).
//! * **fifo vs affinity** — under fifo admission a cold request at the
//!   queue head stalls the instance for the cold model's whole batching
//!   window while hot batches sit ready; affinity admission serves them
//!   past it. At an equal pod budget, affinity batching must serve
//!   strictly MORE than fifo once loads are costed — asserted below.
//!
//! Run: `cargo bench --bench warm_load_ablation`
//! Smoke: `SUPERSONIC_SMOKE=1 cargo bench --bench warm_load_ablation`
//! (costed-affinity arm only, compressed, liveness only)

use std::time::Duration;

use supersonic::config::BatchMode;
use supersonic::deployment::Deployment;
use supersonic::experiments::{modelmesh_workload, warm_load_config};
use supersonic::util::bench::{smoke, Csv, Table};
use supersonic::workload::Schedule;

const LOAD_DELAY: Duration = Duration::from_secs(3);
const PHASE: Duration = Duration::from_secs(40);
const CLIENTS: usize = 16;

struct Row {
    label: String,
    ok: u64,
    shed: u64,
    errors: u64,
    phase_a_ok: u64,
    phase_b_ok: u64,
    load_events: f64,
    latency_ms: f64,
}

fn run_arm(load_delay: Duration, mode: BatchMode, time_scale: f64) -> anyhow::Result<Row> {
    let cfg = warm_load_config(time_scale, load_delay, mode);
    let label = cfg.name.clone();
    let d = Deployment::up(cfg)?;
    anyhow::ensure!(d.wait_ready(4, Duration::from_secs(60)), "fleet not ready");
    // Phase A: 90/10 hot/cold. Phase B: the flip — cold becomes hot and
    // placement must migrate (paying load windows in the costed arms).
    let phase_a = modelmesh_workload(&d.endpoint(), 0.9, d.clock.clone());
    let report_a = phase_a.run(&Schedule::constant(CLIENTS, PHASE));
    let phase_b = modelmesh_workload(&d.endpoint(), 0.1, d.clock.clone());
    let report_b = phase_b.run(&Schedule::constant(CLIENTS, PHASE));
    let load_events = d.store.sum_latest_prefix("model_load_events_total");
    let latency_ms = (report_a.overall_latency.mean() + report_b.overall_latency.mean()) / 2.0
        * 1e3;
    let row = Row {
        label,
        ok: report_a.total_ok() + report_b.total_ok(),
        shed: report_a.total_shed() + report_b.total_shed(),
        errors: report_a.total_errors() + report_b.total_errors(),
        phase_a_ok: report_a.total_ok(),
        phase_b_ok: report_b.total_ok(),
        load_events,
        latency_ms,
    };
    d.down();
    Ok(row)
}

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    println!("== warm-load ablation: instant vs costed loads x fifo vs affinity batching ==");
    if smoke() {
        let row = run_arm(LOAD_DELAY, BatchMode::Affinity, 20.0)?;
        println!("(smoke) costed-affinity arm: {} ok, {:.0} loads", row.ok, row.load_events);
        assert!(row.ok > 0, "costed-affinity arm served nothing");
        return Ok(());
    }
    let time_scale = 10.0;
    println!(
        "4 instances (budget fits both models), {CLIENTS} clients, 90/10 skew then \
         flipped, {}s clock per phase, {}s load delay in costed arms \
         (time_scale {time_scale}x)\n",
        PHASE.as_secs(),
        LOAD_DELAY.as_secs(),
    );

    let mut rows = Vec::new();
    for (delay, mode) in [
        (Duration::ZERO, BatchMode::Fifo),
        (Duration::ZERO, BatchMode::Affinity),
        (LOAD_DELAY, BatchMode::Fifo),
        (LOAD_DELAY, BatchMode::Affinity),
    ] {
        let row = run_arm(delay, mode, time_scale)?;
        eprintln!("{} done ({} ok, {:.0} loads)", row.label, row.ok, row.load_events);
        rows.push(row);
    }

    let mut table = Table::new(&[
        "arm", "ok", "shed", "err", "phase A ok", "phase B ok", "loads",
        "mean latency (ms)",
    ]);
    let mut csv = Csv::new(&[
        "arm", "ok", "shed", "errors", "phase_a_ok", "phase_b_ok", "load_events",
        "mean_latency_ms",
    ]);
    for r in &rows {
        table.row(&[
            r.label.clone(),
            r.ok.to_string(),
            r.shed.to_string(),
            r.errors.to_string(),
            r.phase_a_ok.to_string(),
            r.phase_b_ok.to_string(),
            format!("{:.0}", r.load_events),
            format!("{:.1}", r.latency_ms),
        ]);
        csv.row(&[
            r.label.clone(),
            r.ok.to_string(),
            r.shed.to_string(),
            r.errors.to_string(),
            r.phase_a_ok.to_string(),
            r.phase_b_ok.to_string(),
            format!("{:.0}", r.load_events),
            format!("{:.2}", r.latency_ms),
        ]);
    }
    println!("{}", table.render());
    let path = csv.save("warm_load_ablation")?;
    println!("CSV: {}", path.display());

    let [instant_fifo, instant_affinity, costed_fifo, costed_affinity] = &rows[..] else {
        anyhow::bail!("expected 4 arms");
    };
    println!("\nchecks (equal pod budget):");
    println!(
        "  instant: fifo {} ok vs affinity {} ok",
        instant_fifo.ok, instant_affinity.ok
    );
    println!(
        "  costed : fifo {} ok vs affinity {} ok ({:.0} / {:.0} loads paid)",
        costed_fifo.ok, costed_affinity.ok, costed_fifo.load_events,
        costed_affinity.load_events
    );
    // The demand flip must actually exercise the cost model: placement
    // paid at least one real load window in every costed arm.
    assert!(
        costed_fifo.load_events >= 1.0 && costed_affinity.load_events >= 1.0,
        "costed arms planned no loads — the flip did not exercise the cost model"
    );
    // The headline: once loads cost something, model-affinity batching
    // serves strictly more than naive fifo batching at the same budget.
    assert!(
        costed_affinity.ok > costed_fifo.ok,
        "affinity batching should serve strictly more than fifo at an equal pod \
         budget with costed loads (affinity {} vs fifo {})",
        costed_affinity.ok,
        costed_fifo.ok
    );
    Ok(())
}
