//! §2.1 ablation — Triton dynamic batching.
//!
//! Sweeps the two dynamic-batching knobs on the *real* PJRT-compiled
//! ParticleNet (whose per-row cost drops sharply with batch size, like a
//! GPU) under 8 concurrent closed-loop clients:
//!
//!   * `max_queue_delay` — how long the batcher may hold the head request
//!     while accumulating a batch;
//!   * `preferred_batch` — the row count at which it stops accumulating.
//!
//! Reports throughput and latency per cell: the throughput win of
//! batching (vs preferred_batch=1) and the latency cost of holding
//! requests too long.
//!
//! Run: `cargo bench --bench batcher_ablation`
//! Smoke: `SUPERSONIC_SMOKE=1 cargo bench --bench batcher_ablation`
//! (simulated execution instead of PJRT, one grid cell, liveness only)

use std::sync::{Arc, RwLock};
use std::time::Duration;

use supersonic::config::{ExecutionMode, GatewayConfig, ModelConfig};
use supersonic::gateway::Gateway;
use supersonic::metrics::Registry;
use supersonic::server::{Instance, ModelRepository};
use supersonic::telemetry::Tracer;
use supersonic::util::bench::{smoke, smoke_scaled, Csv, Table};
use supersonic::util::clock::Clock;
use supersonic::runtime::PjrtRuntime;
use supersonic::workload::{ClientPool, Schedule, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    println!("== §2.1 ablation: dynamic batching sweep (real ParticleNet via PJRT) ==\n");

    // Smoke mode runs without the PJRT native library (absent in CI):
    // metadata-only repository + simulated execution, one grid cell.
    let (repo, exec_mode) = if smoke() {
        let repo = Arc::new(ModelRepository::load_metadata(
            std::path::Path::new("artifacts"),
            &["particlenet".into()],
        )?);
        (repo, ExecutionMode::Simulated)
    } else {
        let runtime = PjrtRuntime::cpu()?;
        let repo = Arc::new(ModelRepository::load(
            &runtime,
            std::path::Path::new("artifacts"),
            &["particlenet".into()],
        )?);
        (repo, ExecutionMode::Real)
    };
    let clock = Clock::real();

    let delays_ms: Vec<u64> = if smoke() { vec![2] } else { vec![0, 2, 5, 20] };
    let preferred: Vec<usize> = if smoke() { vec![8] } else { vec![1, 4, 16] };
    let run_secs = smoke_scaled(8, 3) as u64;

    let mut table = Table::new(&[
        "queue delay", "preferred batch", "ok", "req/s", "rows/s", "p50 ms", "p99 ms",
    ]);
    let mut csv = Csv::new(&["delay_ms", "preferred", "ok", "rps", "rows_per_s", "p50_ms", "p99_ms"]);

    for &delay_ms in &delays_ms {
        for &pref in &preferred {
            let registry = Registry::new();
            let inst = Instance::start_with_mode(
                "ba-0",
                Arc::clone(&repo),
                &[ModelConfig {
                    name: "particlenet".into(),
                    max_queue_delay: Duration::from_millis(delay_ms),
                    preferred_batch: pref,
                    ..ModelConfig::default()
                }],
                clock.clone(),
                registry.clone(),
                256,
                5.0,
                exec_mode,
            );
            inst.mark_ready();
            let endpoints = Arc::new(RwLock::new(vec![Arc::clone(&inst)]));
            let gateway = Gateway::start(
                &GatewayConfig::default(),
                endpoints,
                clock.clone(),
                registry,
                Tracer::disabled(),
                None,
            )?;

            // 8 clients, 1 row each: batching must come from the server.
            let spec = WorkloadSpec::new("particlenet", 1, vec![64, 7]);
            let pool = ClientPool::new(&gateway.addr().to_string(), spec, clock.clone());
            let report = pool.run(&Schedule::constant(8, Duration::from_secs(run_secs)));
            let p = &report.phases[0];
            anyhow::ensure!(p.ok > 0, "cell delay={delay_ms}ms pref={pref} served nothing");

            table.row(&[
                format!("{delay_ms} ms"),
                pref.to_string(),
                p.ok.to_string(),
                format!("{:.0}", p.throughput()),
                format!("{:.0}", p.row_rate(1)),
                format!("{:.1}", p.latency.quantile(0.5) * 1e3),
                format!("{:.1}", p.latency.quantile(0.99) * 1e3),
            ]);
            csv.row(&[
                delay_ms.to_string(),
                pref.to_string(),
                p.ok.to_string(),
                format!("{:.1}", p.throughput()),
                format!("{:.1}", p.row_rate(1)),
                format!("{:.2}", p.latency.quantile(0.5) * 1e3),
                format!("{:.2}", p.latency.quantile(0.99) * 1e3),
            ]);
            eprintln!("delay={delay_ms}ms preferred={pref}: {:.0} req/s", p.throughput());

            gateway.shutdown();
            inst.stop();
        }
    }

    println!("{}", table.render());
    let path = csv.save("batcher_ablation")?;
    println!("CSV: {}", path.display());
    println!(
        "\nexpectation: preferred_batch > 1 raises throughput substantially\n\
         (ParticleNet per-row cost falls with batch); very long queue delays\n\
         trade p50 latency for little extra throughput once batches fill."
    );
    Ok(())
}
