//! Control-plane observability — the flight-recorder acceptance bench.
//!
//! One 3-site federated deployment (gateway homed at the first site)
//! carries steady traced traffic while the WHOLE home site is killed
//! mid-run and later recovered. Asserted:
//!
//! 1. **Explainability** — the flight recorder reconstructs the outage
//!    incident with zero missing links and in timestamp order:
//!    `site_outage` -> `budget_shift` -> `spillover`/`failover` ->
//!    `site_recovered` -> `repatriation`, and `supersonic explain`'s
//!    rendering of the ledger is non-empty.
//! 2. **Cross-site trace propagation** — spilled requests fold a
//!    site-labeled `wan` stage, and the per-stage sums reconstruct the
//!    end-to-end (`request_total_seconds`) latency within 5%.
//! 3. **Overhead** — recorder-on throughput is within 5% of a
//!    recorder-off arm (`flight_recorder_capacity: 0`) carrying the
//!    same schedule through the same outage.
//!
//! Run: `cargo bench --bench control_plane_observability`
//! Smoke: `SUPERSONIC_SMOKE=1 cargo bench --bench control_plane_observability`

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use supersonic::config::*;
use supersonic::deployment::Deployment;
use supersonic::metrics::exposition::render;
use supersonic::telemetry::flight::ExplainFilter;
use supersonic::util::bench::{smoke, Csv, Table};
use supersonic::workload::{ClientPool, Schedule, WorkloadSpec};

const TIME_SCALE: f64 = 8.0;
const HOME: &str = "purdue";

fn site(name: &str, wan: &[(&str, f64)]) -> SiteConfig {
    SiteConfig {
        name: name.into(),
        pod_budget: 4,
        replicas: 2,
        nodes: 2,
        gpus_per_node: 2,
        cpu_replicas: 0,
        wan: wan
            .iter()
            .map(|(p, s)| (p.to_string(), Duration::from_secs_f64(*s)))
            .collect::<BTreeMap<_, _>>(),
    }
}

fn bench_cfg(name: &str, recorder_capacity: usize) -> DeploymentConfig {
    DeploymentConfig {
        name: name.into(),
        server: ServerConfig {
            replicas: 2,
            models: vec![ModelConfig {
                name: "icecube_cnn".into(),
                max_queue_delay: Duration::from_millis(1),
                preferred_batch: 8,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(2),
                    per_row: Duration::from_micros(100),
                },
                ..ModelConfig::default()
            }],
            repository: "artifacts".into(),
            startup_delay: Duration::from_millis(50),
            execution: ExecutionMode::Simulated,
            queue_capacity: 512,
            util_window: 10.0,
            batch_mode: Default::default(),
            priorities: Default::default(),
        },
        gateway: GatewayConfig::default(),
        autoscaler: AutoscalerConfig {
            enabled: true,
            min_replicas: 1,
            max_replicas: 6,
            poll_interval: Duration::from_millis(500),
            per_model: PerModelScalingConfig {
                enabled: true,
                // The bench exercises outage/repatriation, not scale-ups:
                // keep the pod counts stable.
                threshold: 10_000.0,
                min_replicas: 1,
                max_replicas: 4,
            },
            ..AutoscalerConfig::default()
        },
        cluster: ClusterConfig {
            nodes: 3,
            gpus_per_node: 2,
            pod_start_delay: Duration::from_millis(50),
            termination_grace: Duration::from_millis(50),
            pod_failure_rate: 0.0,
        },
        federation: FederationConfig {
            sites: vec![
                site(HOME, &[("nrp", 0.002), ("uchicago", 0.004)]),
                site("nrp", &[]),
                site("uchicago", &[]),
            ],
            gateway_site: HOME.into(),
            rebalance_interval: Duration::from_millis(500),
            spillover_queue_depth: 4.0,
        },
        monitoring: MonitoringConfig {
            listen: String::new(),
            scrape_interval: Duration::from_secs(1),
            retention: Duration::from_secs(3600),
            tracing: true,
        },
        model_placement: ModelPlacementConfig {
            memory_budget_mb: 4096.0,
            ..ModelPlacementConfig::default()
        },
        engines: Default::default(),
        observability: ObservabilityConfig {
            trace_sample_rate: 1.0,
            trace_capacity: 65536,
            flight_recorder_capacity: recorder_capacity,
            ..ObservabilityConfig::default()
        },
        rpc: Default::default(),
        time_scale: TIME_SCALE,
    }
}

/// One arm's observable outcome, captured before teardown.
struct Arm {
    ok: u64,
    errors: u64,
    /// (complete, in_order) for the home-site outage chain, if a
    /// recorder was armed.
    chain: Option<(bool, bool)>,
    explain: String,
    /// Sum over every `request_stage_seconds` series (all label sets).
    stage_sum: f64,
    /// `request_total_seconds` sum (root-span durations).
    total_sum: f64,
    /// A `wan` stage labeled with a non-local serving site exists.
    wan_site: bool,
}

/// Fold the exposition text into the reconstruction inputs: the summed
/// per-stage time, the summed end-to-end time, and whether any spilled
/// request left a site-labeled `wan` series behind.
fn fold_exposition(text: &str) -> (f64, f64, bool) {
    let value = |line: &str| line.rsplit(' ').next().unwrap_or("0").parse::<f64>().unwrap_or(0.0);
    let mut stage_sum = 0.0;
    let mut total_sum = 0.0;
    let mut wan_site = false;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("request_stage_seconds_sum") {
            stage_sum += value(line);
            if rest.contains("stage=\"wan\"") && !rest.contains("site=\"local\"") {
                wan_site = true;
            }
        } else if line.starts_with("request_total_seconds_sum") {
            total_sum = value(line);
        }
    }
    (stage_sum, total_sum, wan_site)
}

/// Boot the federation, drive `3 * phase` of steady traced traffic with
/// the home site dead for the middle third, and capture the arm outcome.
fn run_arm(name: &str, recorder_capacity: usize, phase: Duration) -> anyhow::Result<Arm> {
    let d = Deployment::up(bench_cfg(name, recorder_capacity))?;
    let fed = Arc::clone(d.federation.as_ref().expect("federated deployment"));
    anyhow::ensure!(d.wait_ready(6, Duration::from_secs(30)), "federated fleet not ready");
    let spec = WorkloadSpec::new("icecube_cnn", 4, vec![16, 16, 3]).with_tracing();
    let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
    let schedule = Schedule::constant(6, 3 * phase);
    let h = std::thread::spawn(move || pool.run(&schedule));

    d.clock.sleep(phase);
    anyhow::ensure!(fed.fail_site(HOME), "fail_site({HOME})");
    d.clock.sleep(phase);
    anyhow::ensure!(fed.recover_site(HOME), "recover_site({HOME})");
    let report = h.join().unwrap();

    let (chain, explain) = match &d.flight {
        Some(f) => {
            let chains = f.outage_chains();
            let home = chains.iter().find(|c| c.site == HOME);
            (
                Some((
                    home.map(|c| c.complete()).unwrap_or(false),
                    home.map(|c| c.in_order()).unwrap_or(false),
                )),
                f.explain(&ExplainFilter::default()),
            )
        }
        None => (None, String::new()),
    };
    let (stage_sum, total_sum, wan_site) = fold_exposition(&render(&d.registry));
    d.down();
    Ok(Arm {
        ok: report.total_ok,
        errors: report.total_errors,
        chain,
        explain,
        stage_sum,
        total_sum,
        wan_site,
    })
}

/// The explainability + reconstruction acceptance checks (both modes).
fn check_recorder_arm(arm: &Arm) -> anyhow::Result<()> {
    anyhow::ensure!(arm.ok > 0, "no requests served");
    anyhow::ensure!(arm.errors == 0, "request errors across the outage");
    let (complete, in_order) = arm.chain.expect("recorder-on arm has a ledger");
    anyhow::ensure!(
        complete,
        "outage chain has missing links:\n{}",
        arm.explain
    );
    anyhow::ensure!(
        in_order,
        "outage chain links are out of timestamp order:\n{}",
        arm.explain
    );
    anyhow::ensure!(
        arm.explain.contains("site_outage") && arm.explain.contains("repatriation"),
        "explain output does not render the incident:\n{}",
        arm.explain
    );
    anyhow::ensure!(
        arm.wan_site,
        "no site-labeled wan stage: spilled requests lost their WAN hop"
    );
    anyhow::ensure!(arm.total_sum > 0.0, "no traced requests folded");
    let drift = (arm.stage_sum - arm.total_sum).abs() / arm.total_sum;
    anyhow::ensure!(
        drift <= 0.05,
        "stage breakdown does not reconstruct end-to-end latency: \
         stages {:.3}s vs total {:.3}s ({:.1}% drift)",
        arm.stage_sum,
        arm.total_sum,
        drift * 100.0
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    if smoke() {
        println!("== control-plane observability (smoke): short outage slice ==");
        let arm = run_arm("cpobs-smoke", 4096, Duration::from_secs(5))?;
        check_recorder_arm(&arm)?;
        println!(
            "(smoke) {} ok, chain complete and ordered, stages {:.2}s vs total {:.2}s",
            arm.ok, arm.stage_sum, arm.total_sum
        );
        return Ok(());
    }

    println!("== control-plane observability: recorder on/off through a site outage ==");
    let phase = Duration::from_secs(10);
    let mut table = Table::new(&["arm", "ok", "errors", "stage sum (s)", "total sum (s)"]);
    let mut csv = Csv::new(&["arm", "ok", "errors", "stage_sum_s", "total_sum_s"]);

    println!("-- recorder-off arm (flight_recorder_capacity: 0)");
    let off = run_arm("cpobs-off", 0, phase)?;
    anyhow::ensure!(off.ok > 0, "recorder-off arm served nothing");
    anyhow::ensure!(off.chain.is_none(), "capacity 0 must disable the recorder");

    println!("-- recorder-on arm (default capacity)");
    let on = run_arm("cpobs-on", 4096, phase)?;
    check_recorder_arm(&on)?;

    for (name, arm) in [("recorder-off", &off), ("recorder-on", &on)] {
        let cells = [
            name.to_string(),
            arm.ok.to_string(),
            arm.errors.to_string(),
            format!("{:.3}", arm.stage_sum),
            format!("{:.3}", arm.total_sum),
        ];
        table.row(&cells);
        csv.row(&cells);
    }
    println!("\n{}", table.render());
    let path = csv.save("control_plane_observability")?;
    println!("CSV: {}", path.display());
    println!("\n{}", on.explain);

    anyhow::ensure!(
        on.ok as f64 >= 0.95 * off.ok as f64,
        "flight recorder costs more than 5% throughput: on {} vs off {}",
        on.ok,
        off.ok
    );
    Ok(())
}
