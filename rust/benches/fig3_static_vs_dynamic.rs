//! Fig. 3 — "Average GPU utilization and latency for a test workflow with
//! an inference load that varies over time. Dynamic GPU provisioning with
//! SuperSONIC (red) outperforms setups with fixed GPU count (blue)."
//!
//! Runs the same 1 → 10 → 1 workload against static deployments with
//! N ∈ {1, 2, 4, 10} GPU servers and against the dynamic (autoscaled)
//! deployment, and prints the (avg latency, avg GPU utilization) pairs
//! that the paper's scatter plot shows.
//!
//! Run: `cargo bench --bench fig3_static_vs_dynamic`
//! Smoke: `SUPERSONIC_SMOKE=1 cargo bench --bench fig3_static_vs_dynamic`
//! (two arms, compressed phases, liveness checks only)

use std::time::Duration;

use supersonic::experiments::{fig_config, fig_workload, run_deployment};
use supersonic::util::bench::{smoke, smoke_scaled, Csv, Table};
use supersonic::workload::Schedule;

struct Row {
    label: String,
    latency_ms: f64,
    p99_ms: f64,
    utilization: f64,
    ok: u64,
    peak_servers: usize,
}

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    println!("== Fig. 3: static vs dynamic GPU allocation ==");

    // Faster dilation than Fig. 2 — five configurations to run.
    let time_scale = if smoke() { 24.0 } else { 12.0 };
    let phase = Duration::from_secs(smoke_scaled(180, 45) as u64);
    let schedule = Schedule::step_up_down(1, 10, phase);
    println!(
        "workload: 1 -> 10 -> 1 clients x {}s clock phases (time_scale {}x)\n",
        phase.as_secs(),
        time_scale
    );

    let arms: Vec<Option<usize>> = if smoke() {
        vec![Some(1), None] // one static arm + dynamic, liveness only
    } else {
        vec![Some(1), Some(2), Some(4), Some(10), None]
    };
    let mut rows: Vec<Row> = Vec::new();
    for static_n in arms {
        let label = match static_n {
            Some(n) => format!("static-{n}"),
            None => "dynamic".to_string(),
        };
        eprintln!("running {label}...");
        let cfg = fig_config(time_scale, static_n, phase);
        let result = run_deployment(cfg, fig_workload(), &schedule, Duration::from_secs(5))?;
        rows.push(Row {
            label,
            latency_ms: result.overall_latency.mean() * 1e3,
            p99_ms: result.overall_latency.quantile(0.99) * 1e3,
            utilization: result.mean_utilization,
            ok: result.report.total_ok,
            peak_servers: result.peak_servers,
        });
    }

    let mut table = Table::new(&[
        "config", "avg latency (ms)", "p99 (ms)", "avg GPU util", "requests ok", "peak servers",
    ]);
    let mut csv = Csv::new(&["config", "avg_latency_ms", "p99_ms", "avg_gpu_utilization", "ok", "peak_servers"]);
    for r in &rows {
        table.row(&[
            r.label.clone(),
            format!("{:.1}", r.latency_ms),
            format!("{:.1}", r.p99_ms),
            format!("{:.3}", r.utilization),
            r.ok.to_string(),
            r.peak_servers.to_string(),
        ]);
        csv.row(&[
            r.label.clone(),
            format!("{:.2}", r.latency_ms),
            format!("{:.2}", r.p99_ms),
            format!("{:.4}", r.utilization),
            r.ok.to_string(),
            r.peak_servers.to_string(),
        ]);
    }
    println!("{}", table.render());
    let path = csv.save("fig3_static_vs_dynamic")?;
    println!("CSV: {}", path.display());

    assert!(rows.iter().all(|r| r.ok > 0), "an arm served nothing");
    if smoke() {
        println!("\n(smoke: static-vs-dynamic assertions skipped — phases too short)");
        return Ok(());
    }

    // The paper's qualitative claims.
    let get = |label: &str| rows.iter().find(|r| r.label == label).unwrap();
    let dynamic = get("dynamic");
    let static1 = get("static-1");
    let static10 = get("static-10");
    println!("\nchecks (paper: dynamic beats both static extremes):");
    println!(
        "  static-1  : latency {:.0}ms (overloaded at peak), util {:.2}",
        static1.latency_ms, static1.utilization
    );
    println!(
        "  static-10 : latency {:.0}ms, util {:.2} (wasteful at light load)",
        static10.latency_ms, static10.utilization
    );
    println!(
        "  dynamic   : latency {:.0}ms, util {:.2}",
        dynamic.latency_ms, dynamic.utilization
    );
    assert!(
        dynamic.latency_ms < static1.latency_ms,
        "dynamic latency should beat the undersized static deployment"
    );
    assert!(
        dynamic.utilization > static10.utilization,
        "dynamic utilization should beat the oversized static deployment"
    );
    Ok(())
}
