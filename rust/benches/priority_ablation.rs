//! Priority ablation — request-priority lanes vs the priority-blind
//! baseline, same traffic, same two-server pod budget.
//!
//! Setup (see `experiments::priority_config`): two simulated GPU servers
//! serving one model behind row-bounded 64-row queues, driven by a
//! mixed-criticality workload — a saturating 8-row `bulk` stream plus a
//! light 1-row latency-`critical` stream (trigger-style inference next
//! to offline reprocessing on shared servers, the CMS SONIC scenario).
//!
//! The two arms carry IDENTICAL traffic and differ only in tagging:
//!
//! * **`prio-blind`** — both streams run `standard`: one admission lane,
//!   critical requests wait behind the whole bulk backlog and are
//!   rejected at ingress whenever bulk fills the queue first.
//! * **`prio-lanes`** — streams tagged `bulk` / `critical`: expired
//!   critical heads are served first (preempting accumulating bulk
//!   windows), and a full queue evicts its newest bulk request instead
//!   of rejecting the incoming critical one (shed-from-bulk).
//!
//! The headline assertion: critical p99 in the lanes arm is at least 2x
//! better than the blind baseline at the same pod budget — while bulk
//! still makes progress (no total starvation) and real preemptions were
//! recorded.
//!
//! Run: `cargo bench --bench priority_ablation` (or `make bench-priority`)
//! Smoke: `SUPERSONIC_SMOKE=1 cargo bench --bench priority_ablation`
//! (lanes arm only, compressed, liveness only)

use std::time::Duration;

use supersonic::deployment::Deployment;
use supersonic::experiments::{priority_config, priority_workload};
use supersonic::util::bench::{smoke, Csv, Table};
use supersonic::workload::Schedule;

const PHASE: Duration = Duration::from_secs(40);
const CLIENTS: usize = 14;

struct Row {
    label: String,
    crit_ok: u64,
    crit_shed: u64,
    crit_mean_ms: f64,
    crit_p99_ms: f64,
    bulk_ok: u64,
    bulk_shed: u64,
    preemptions: f64,
}

fn run_arm(lanes: bool, time_scale: f64) -> anyhow::Result<Row> {
    let name = if lanes { "prio-lanes" } else { "prio-blind" };
    let cfg = priority_config(time_scale, name);
    let d = Deployment::up(cfg)?;
    anyhow::ensure!(d.wait_ready(2, Duration::from_secs(60)), "fleet not ready");
    let pool = priority_workload(&d.endpoint(), lanes, d.clock.clone());
    let report = pool.run(&Schedule::constant(CLIENTS, PHASE));
    let bulk = &report.per_entry[0];
    let crit = &report.per_entry[1];
    let row = Row {
        label: name.into(),
        crit_ok: crit.ok,
        crit_shed: crit.shed,
        crit_mean_ms: crit.latency.mean() * 1e3,
        crit_p99_ms: crit.latency.quantile(0.99) * 1e3,
        bulk_ok: bulk.ok,
        bulk_shed: bulk.shed,
        preemptions: d.store.sum_latest_prefix("batch_preemptions_total"),
    };
    d.down();
    Ok(row)
}

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    println!("== priority ablation: admission lanes vs priority-blind, equal pod budget ==");
    if smoke() {
        let row = run_arm(true, 20.0)?;
        println!("(smoke) lanes arm: {} critical ok, {} bulk ok", row.crit_ok, row.bulk_ok);
        assert!(row.crit_ok > 0, "lanes arm served no critical requests");
        return Ok(());
    }
    let time_scale = 4.0;
    println!(
        "2 instances, {CLIENTS} clients (85% 8-row bulk / 15% 1-row critical), \
         {}s clock per arm (time_scale {time_scale}x)\n",
        PHASE.as_secs(),
    );

    let blind = run_arm(false, time_scale)?;
    eprintln!("{} done ({} critical ok)", blind.label, blind.crit_ok);
    let lanes = run_arm(true, time_scale)?;
    eprintln!("{} done ({} critical ok)", lanes.label, lanes.crit_ok);

    let mut table = Table::new(&[
        "arm", "crit ok", "crit shed", "crit mean (ms)", "crit p99 (ms)", "bulk ok",
        "bulk shed", "preemptions",
    ]);
    let mut csv = Csv::new(&[
        "arm", "crit_ok", "crit_shed", "crit_mean_ms", "crit_p99_ms", "bulk_ok",
        "bulk_shed", "preemptions",
    ]);
    for r in [&blind, &lanes] {
        table.row(&[
            r.label.clone(),
            r.crit_ok.to_string(),
            r.crit_shed.to_string(),
            format!("{:.1}", r.crit_mean_ms),
            format!("{:.1}", r.crit_p99_ms),
            r.bulk_ok.to_string(),
            r.bulk_shed.to_string(),
            format!("{:.0}", r.preemptions),
        ]);
        csv.row(&[
            r.label.clone(),
            r.crit_ok.to_string(),
            r.crit_shed.to_string(),
            format!("{:.2}", r.crit_mean_ms),
            format!("{:.2}", r.crit_p99_ms),
            r.bulk_ok.to_string(),
            r.bulk_shed.to_string(),
            format!("{:.0}", r.preemptions),
        ]);
    }
    println!("{}", table.render());
    let path = csv.save("priority_ablation")?;
    println!("CSV: {}", path.display());

    println!("\nchecks (equal pod budget, identical traffic):");
    println!(
        "  critical p99: blind {:.1} ms vs lanes {:.1} ms",
        blind.crit_p99_ms, lanes.crit_p99_ms
    );
    println!(
        "  critical shed: blind {} vs lanes {} ({:.0} preemptions)",
        blind.crit_shed, lanes.crit_shed, lanes.preemptions
    );
    // Enough critical completions for the percentile to mean something.
    assert!(
        blind.crit_ok > 20 && lanes.crit_ok > 20,
        "critical sample too small (blind {}, lanes {})",
        blind.crit_ok,
        lanes.crit_ok
    );
    // The lanes actually did something: real preemptions, and bulk still
    // progressed (bounded starvation, not a bulk blackout).
    assert!(
        lanes.preemptions >= 1.0,
        "no preemptions recorded in the lanes arm"
    );
    assert!(lanes.bulk_ok > 0, "bulk starved entirely under the lanes");
    // The headline: under bulk saturation, critical p99 with lanes is at
    // least 2x better than the priority-blind baseline.
    assert!(
        lanes.crit_p99_ms * 2.0 <= blind.crit_p99_ms,
        "priority lanes should improve critical p99 at least 2x at an equal pod \
         budget (lanes {:.1} ms vs blind {:.1} ms)",
        lanes.crit_p99_ms,
        blind.crit_p99_ms
    );
    Ok(())
}
