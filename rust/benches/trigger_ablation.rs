//! §2.4 ablation — autoscaler trigger metrics.
//!
//! The paper: "The default scaling metric is defined as the average
//! request queue latency across Triton servers" and "the trade-off ...
//! can be further adjusted by tuning ... the metric used as its
//! trigger." This ablation runs the Fig. 2 workload against the same
//! dynamic deployment under four trigger choices:
//!
//!   * `queue_latency_avg`   — windowed Δqueue_time/Δrequests (default;
//!                             Triton+KEDA semantics)
//!   * `queue_latency_ewma`  — smoothed instantaneous gauge
//!   * `queue_depth_avg`     — queued requests per instance
//!   * `gpu_utilization_avg` — busy fraction
//!
//! and reports scaling behaviour + client latency per trigger. Thresholds
//! are per-metric (they measure different quantities) and chosen to target
//! the same knee.
//!
//! Run: `cargo bench --bench trigger_ablation`
//! Smoke: `SUPERSONIC_SMOKE=1 cargo bench --bench trigger_ablation`
//! (default trigger only, compressed phases, liveness only)

use std::time::Duration;

use supersonic::experiments::{fig_config, fig_workload, run_deployment};
use supersonic::util::bench::{smoke, smoke_scaled, Csv, Table};
use supersonic::workload::Schedule;

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    println!("== §2.4 ablation: autoscaler trigger metrics ==");

    let time_scale = if smoke() { 24.0 } else { 12.0 };
    let phase = Duration::from_secs(smoke_scaled(180, 45) as u64);
    let schedule = Schedule::step_up_down(1, 10, phase);

    // (metric, threshold): thresholds target the same ~4-server knee.
    let triggers: Vec<(&str, f64)> = if smoke() {
        vec![("queue_latency_avg:30", 0.025)] // paper default only
    } else {
        vec![
            ("queue_latency_avg:30", 0.025), // seconds of queue wait/request
            ("queue_latency_ewma", 0.025),   // seconds (smoothed gauge)
            ("queue_depth_avg", 1.0),        // requests waiting per instance
            ("gpu_utilization_avg", 0.85),   // busy fraction
        ]
    };

    let mut table = Table::new(&[
        "trigger", "peak servers", "avg latency (ms)", "p99 (ms)", "avg util", "ok",
    ]);
    let mut csv = Csv::new(&["trigger", "peak_servers", "avg_latency_ms", "p99_ms", "avg_util", "ok"]);

    for (metric, threshold) in triggers {
        eprintln!("running trigger {metric}...");
        let mut cfg = fig_config(time_scale, None, phase);
        cfg.autoscaler.metric = metric.to_string();
        cfg.autoscaler.threshold = threshold;
        let result = run_deployment(cfg, fig_workload(), &schedule, Duration::from_secs(5))?;
        anyhow::ensure!(result.report.total_ok > 0, "trigger {metric} served nothing");
        table.row(&[
            metric.to_string(),
            result.peak_servers.to_string(),
            format!("{:.1}", result.overall_latency.mean() * 1e3),
            format!("{:.1}", result.overall_latency.quantile(0.99) * 1e3),
            format!("{:.3}", result.mean_utilization),
            result.report.total_ok.to_string(),
        ]);
        csv.row(&[
            metric.to_string(),
            result.peak_servers.to_string(),
            format!("{:.2}", result.overall_latency.mean() * 1e3),
            format!("{:.2}", result.overall_latency.quantile(0.99) * 1e3),
            format!("{:.4}", result.mean_utilization),
            result.report.total_ok.to_string(),
        ]);
    }

    println!("{}", table.render());
    let path = csv.save("trigger_ablation")?;
    println!("CSV: {}", path.display());
    println!(
        "\nexpectation: the windowed per-request trigger (paper default) scales\n\
         decisively on the load step; the smoothed gauge under-reports sustained\n\
         overload (scales less / later); utilization triggers scale on busyness\n\
         even when latency is acceptable."
    );
    Ok(())
}
