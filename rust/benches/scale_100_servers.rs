//! §3 scale test — "a SuperSONIC deployment at the National Research
//! Platform (NRP) was tested with as many as 100 GPU-enabled Triton
//! servers."
//!
//! Boots the `configs/nrp.yaml` preset pinned to 100 static replicas,
//! measures time-to-ready for all 100, serves a wide closed-loop burst,
//! and reports throughput plus load-balance fairness across instances
//! (max/min/stddev of per-instance request counts).
//!
//! Run: `cargo bench --bench scale_100_servers`
//! Smoke: `SUPERSONIC_SMOKE=1 cargo bench --bench scale_100_servers`
//! (12-replica fleet, shorter burst — same code path, smaller scale)

use std::time::Duration;

use supersonic::config::DeploymentConfig;
use supersonic::deployment::Deployment;
use supersonic::metrics::registry::SampleValue;
use supersonic::util::bench::{smoke_scaled, Table};
use supersonic::workload::{ClientPool, Schedule, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    let replicas = smoke_scaled(100, 12);
    println!("== NRP-scale: {replicas} GPU-enabled inference servers (§3) ==\n");

    let mut cfg = DeploymentConfig::from_file(std::path::Path::new("configs/nrp.yaml"))?;
    // Pin the replica count: this bench measures scale, not scaling.
    cfg.autoscaler.enabled = false;
    cfg.server.replicas = replicas;
    cfg.cluster.pod_failure_rate = 0.0;
    cfg.server.startup_delay = Duration::from_secs(5);
    cfg.cluster.pod_start_delay = Duration::from_secs(10);
    cfg.gateway.auth_secret = None;
    cfg.time_scale = 20.0;
    cfg.validate()?;

    let t0 = std::time::Instant::now();
    let d = Deployment::up(cfg)?;
    anyhow::ensure!(
        d.wait_ready(replicas, Duration::from_secs(120)),
        "{replicas} instances not ready (got {})",
        d.cluster.running()
    );
    let boot = t0.elapsed();
    println!(
        "{replicas} instances Ready in {:.1}s wall ({:.0}s cluster time)\n",
        boot.as_secs_f64(),
        boot.as_secs_f64() * d.cfg.time_scale
    );

    // Wide burst: 64 clients, 120 clock seconds (16 / 30 in smoke).
    let mut spec = WorkloadSpec::new("particlenet", 16, vec![64, 7]);
    spec.think_time = Duration::from_millis(30);
    let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
    let clients = smoke_scaled(64, 16);
    let burst = Duration::from_secs(smoke_scaled(120, 30) as u64);
    let report = pool.run(&Schedule::constant(clients, burst));
    let p = &report.phases[0];
    anyhow::ensure!(p.ok > 0, "no requests served");

    // Fairness: requests per instance. The counter is created lazily on
    // first request, so pad with zeros up to the full fleet size — an
    // instance that never served counts against fairness.
    let fleet = d.cluster.running();
    let mut per_instance: Vec<f64> = d
        .registry
        .snapshot()
        .into_iter()
        .filter(|s| s.name == "inference_requests_total")
        .map(|s| match s.value {
            SampleValue::Counter(v) => v as f64,
            _ => 0.0,
        })
        .collect();
    while per_instance.len() < fleet {
        per_instance.push(0.0);
    }
    let n = per_instance.len().max(1) as f64;
    let mean = per_instance.iter().sum::<f64>() / n;
    let var = per_instance.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n;
    let served = per_instance.iter().filter(|&&v| v > 0.0).count();
    let max = per_instance.iter().cloned().fold(0.0, f64::max);
    let min = per_instance.iter().cloned().fold(f64::INFINITY, f64::min);

    let mut table = Table::new(&["metric", "value"]);
    table.row(&["instances Ready".into(), format!("{}", d.cluster.running())]);
    table.row(&["time-to-ready (wall)".into(), format!("{:.1}s", boot.as_secs_f64())]);
    table.row(&["requests ok".into(), p.ok.to_string()]);
    table.row(&["throughput".into(), format!("{:.0} req/s (clock)", p.throughput())]);
    table.row(&["inference rate".into(), format!("{:.0} rows/s (clock)", p.row_rate(16))]);
    table.row(&["client p50 / p99".into(), format!(
        "{:.1} / {:.1} ms",
        p.latency.quantile(0.5) * 1e3,
        p.latency.quantile(0.99) * 1e3
    )]);
    table.row(&["instances that served".into(), format!("{served} / {}", per_instance.len())]);
    table.row(&["per-instance req mean".into(), format!("{mean:.1}")]);
    table.row(&["per-instance req min/max".into(), format!("{min:.0} / {max:.0}")]);
    table.row(&["per-instance req stddev".into(), format!("{:.1} ({:.0}% of mean)", var.sqrt(), 100.0 * var.sqrt() / mean.max(1e-9))]);
    println!("{}", table.render());

    assert_eq!(d.cluster.running(), replicas);
    assert!(served as f64 >= 0.95 * per_instance.len() as f64, "load balancing left instances cold");
    println!("checks: all {replicas} served traffic, fairness within expectation.");
    d.down();
    Ok(())
}
