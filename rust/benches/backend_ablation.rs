//! Backend ablation — homogeneous-GPU fleet vs a mixed CPU+GPU fleet at
//! an EQUAL four-pod budget under skewed two-model traffic.
//!
//! Setup (see `experiments::backend_config`): the hot `particlenet`
//! runs anywhere (pjrt preferred, onnx-sim fallback); the cold-but-
//! constant `icecube_cnn` is a cheap **CPU-only** model
//! (`backends: [onnx-sim]`) — the classic auxiliary model no GPU engine
//! exists for. Traffic is 70/30 hot/cold.
//!
//! What the arms show:
//!
//! * **`backend-gpu-only`** (4 GPU pods) — the backend-locked fleet
//!   cannot place the CPU-only model at all: its pool stays empty, its
//!   whole stream is shed, and `model_backend_replicas` reads zero for
//!   it (the "model stuck unplaceable" runbook symptom).
//! * **`backend-mixed-1cpu`** (3 GPU + 1 CPU pod) — the heterogeneous
//!   fleet serves both: the CPU pod hosts the CPU-only model, and the
//!   hot model is boot-placed onto it too via an onnx-sim *fallback*
//!   (pjrt has no capacity on a CPU pod), counted in
//!   `backend_fallback_total`.
//!
//! The headline assertion: at the same pod budget, the mixed fleet
//! serves strictly MORE total requests than the homogeneous fleet —
//! offloading the cold/cheap model to CPU backends costs one GPU pod
//! and buys the whole shed stream back — with at least one backend
//! fallback recorded.
//!
//! Run: `cargo bench --bench backend_ablation` (or `make bench-backend`)
//! Smoke: `SUPERSONIC_SMOKE=1 cargo bench --bench backend_ablation`
//! (mixed-fleet arm only, compressed, liveness only)

use std::time::Duration;

use supersonic::deployment::Deployment;
use supersonic::experiments::{backend_config, backend_workload};
use supersonic::util::bench::{smoke, Csv, Table};
use supersonic::workload::Schedule;

const PHASE: Duration = Duration::from_secs(40);
const CLIENTS: usize = 12;

struct Row {
    label: String,
    ok: u64,
    hot_ok: u64,
    cold_ok: u64,
    cold_shed_err: u64,
    fallbacks: f64,
    latency_ms: f64,
}

fn run_arm(cpu_pods: usize, time_scale: f64) -> anyhow::Result<Row> {
    let cfg = backend_config(time_scale, cpu_pods);
    let label = cfg.name.clone();
    let d = Deployment::up(cfg)?;
    anyhow::ensure!(d.wait_ready(4, Duration::from_secs(60)), "fleet not ready");
    let pool = backend_workload(&d.endpoint(), d.clock.clone());
    let report = pool.run(&Schedule::constant(CLIENTS, PHASE));
    let hot = &report.per_model["particlenet"];
    let cold = &report.per_model["icecube_cnn"];
    let row = Row {
        label,
        ok: report.total_ok(),
        hot_ok: hot.ok,
        cold_ok: cold.ok,
        cold_shed_err: cold.shed + cold.errors,
        fallbacks: d.store.sum_latest_prefix("backend_fallback_total"),
        latency_ms: report.overall_latency.mean() * 1e3,
    };
    d.down();
    Ok(row)
}

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    println!("== backend ablation: homogeneous GPU vs mixed CPU+GPU, equal 4-pod budget ==");
    if smoke() {
        let row = run_arm(1, 20.0)?;
        println!("(smoke) mixed arm: {} ok ({} cold ok)", row.ok, row.cold_ok);
        assert!(row.ok > 0, "mixed arm served nothing");
        return Ok(());
    }
    let time_scale = 10.0;
    println!(
        "{CLIENTS} clients, 70% GPU-capable hot model / 30% CPU-only cold model, \
         {}s clock per arm (time_scale {time_scale}x)\n",
        PHASE.as_secs(),
    );

    let gpu_only = run_arm(0, time_scale)?;
    eprintln!("{} done ({} ok)", gpu_only.label, gpu_only.ok);
    let mixed = run_arm(1, time_scale)?;
    eprintln!("{} done ({} ok)", mixed.label, mixed.ok);

    let mut table = Table::new(&[
        "arm", "ok", "hot ok", "cold ok", "cold shed+err", "fallbacks",
        "mean latency (ms)",
    ]);
    let mut csv = Csv::new(&[
        "arm", "ok", "hot_ok", "cold_ok", "cold_shed_err", "fallbacks",
        "mean_latency_ms",
    ]);
    for r in [&gpu_only, &mixed] {
        table.row(&[
            r.label.clone(),
            r.ok.to_string(),
            r.hot_ok.to_string(),
            r.cold_ok.to_string(),
            r.cold_shed_err.to_string(),
            format!("{:.0}", r.fallbacks),
            format!("{:.1}", r.latency_ms),
        ]);
        csv.row(&[
            r.label.clone(),
            r.ok.to_string(),
            r.hot_ok.to_string(),
            r.cold_ok.to_string(),
            r.cold_shed_err.to_string(),
            format!("{:.0}", r.fallbacks),
            format!("{:.2}", r.latency_ms),
        ]);
    }
    println!("{}", table.render());
    let path = csv.save("backend_ablation")?;
    println!("CSV: {}", path.display());

    println!("\nchecks (equal 4-pod budget, identical traffic):");
    println!(
        "  total ok: gpu-only {} vs mixed {}",
        gpu_only.ok, mixed.ok
    );
    println!(
        "  cold stream: gpu-only {} ok / {} shed+err vs mixed {} ok ({:.0} fallbacks)",
        gpu_only.cold_ok, gpu_only.cold_shed_err, mixed.cold_ok, mixed.fallbacks
    );
    // The homogeneous fleet must demonstrate the failure mode: the
    // CPU-only model is unplaceable there, so nothing is ever served.
    assert_eq!(
        gpu_only.cold_ok, 0,
        "gpu-only arm served a CPU-only model — the compatibility filter leaked"
    );
    assert!(
        gpu_only.cold_shed_err > 0,
        "cold stream produced no traffic in the gpu-only arm"
    );
    // The mixed fleet actually used its heterogeneity: the CPU-only
    // model served, and at least one backend fallback was recorded
    // (the hot model landing on a CPU pod via onnx-sim).
    assert!(mixed.cold_ok > 0, "mixed arm never served the CPU-only model");
    assert!(
        mixed.fallbacks >= 1.0,
        "no backend-fallback event counted in the mixed arm"
    );
    // The headline: heterogeneity wins at an equal pod budget.
    assert!(
        mixed.ok > gpu_only.ok,
        "mixed CPU+GPU fleet should serve strictly more than homogeneous GPU at an \
         equal pod budget (mixed {} vs gpu-only {})",
        mixed.ok,
        gpu_only.ok
    );
    Ok(())
}
