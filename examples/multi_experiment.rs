//! Multi-site portability — §3 "Deployment and Testing".
//!
//! "The SuperSONIC package was deployed with minimal differences on the
//! Geddes and Anvil clusters at Purdue University, at the NRP, and on the
//! ATLAS Analysis Facility at the University of Chicago."
//!
//! This example boots every site preset in `configs/` from the same
//! binary, runs a short representative workload against each (CMS GNN at
//! Purdue, mixed models at NRP, ATLAS-style transformer at UChicago), and
//! prints a per-site summary — demonstrating that one implementation +
//! one config schema covers heterogeneous sites, which is the paper's
//! §3 portability claim.
//!
//! Run: `cargo run --release --example multi_experiment`

use std::time::Duration;

use supersonic::deployment::Deployment;
use supersonic::gateway::auth;
use supersonic::workload::{ClientPool, Schedule, WorkloadSpec};

struct SiteRun {
    site: &'static str,
    config: &'static str,
    /// (model, rows/request, clients)
    workload: (&'static str, usize, usize),
}

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    println!("== SuperSONIC multi-site portability (§3) ==\n");

    let sites = [
        SiteRun {
            site: "Purdue Geddes",
            config: "configs/purdue-geddes.yaml",
            workload: ("particlenet", 16, 4),
        },
        SiteRun {
            site: "Purdue Anvil",
            config: "configs/purdue-anvil.yaml",
            workload: ("particlenet", 16, 8),
        },
        SiteRun {
            site: "NRP",
            config: "configs/nrp.yaml",
            workload: ("icecube_cnn", 16, 8),
        },
        SiteRun {
            site: "UChicago AF",
            config: "configs/uchicago-af.yaml",
            workload: ("cms_transformer", 8, 4),
        },
    ];

    println!(
        "{:<15} {:<22} {:>8} {:>8} {:>9} {:>10} {:>10}",
        "site", "workload", "servers", "ok", "req/s", "p99 ms", "util %"
    );

    for run in &sites {
        let cfg = supersonic::config::DeploymentConfig::from_file(
            std::path::Path::new(run.config),
        )?;
        let boot_replicas = if cfg.autoscaler.enabled {
            cfg.server.replicas.clamp(cfg.autoscaler.min_replicas, cfg.autoscaler.max_replicas)
        } else {
            cfg.server.replicas
        };
        let token = cfg.gateway.auth_secret.as_deref().map(auth::mint_token).unwrap_or_default();
        let d = Deployment::up(cfg)?;
        anyhow::ensure!(
            d.wait_ready(boot_replicas, Duration::from_secs(120)),
            "{}: instances not ready",
            run.site
        );

        let (model, rows, clients) = run.workload;
        let entry = d.repository.get(model).expect("model in preset");
        let mut spec = WorkloadSpec::new(model, rows, entry.input_shape.clone());
        spec.token = token;
        spec.think_time = Duration::from_millis(20);
        let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
        // 60 clock-seconds of steady load (presets use large time_scale,
        // so this is seconds of wall time).
        let report = pool.run(&Schedule::constant(clients, Duration::from_secs(60)));
        let p = &report.phases[0];
        anyhow::ensure!(p.ok > 0, "{}: no successful requests", run.site);
        anyhow::ensure!(
            report.total_errors == 0,
            "{}: {} errors",
            run.site,
            report.total_errors
        );

        let util = d
            .store
            .avg_latest_prefix("gpu_utilization")
            .unwrap_or(0.0);
        println!(
            "{:<15} {:<22} {:>8} {:>8} {:>9.1} {:>10.1} {:>10.1}",
            run.site,
            format!("{model} x{clients}cl"),
            d.cluster.running(),
            p.ok,
            p.throughput(),
            p.latency.quantile(0.99) * 1e3,
            util * 100.0,
        );
        d.down();
    }

    println!("\nall sites served the same binary with config-only differences.");
    Ok(())
}
