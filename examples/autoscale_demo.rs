//! Autoscale demo — the live Fig. 2 driver.
//!
//! Reproduces the paper's §4 experiment interactively: a 1 → 10 → 1
//! client schedule against the `configs/fig2-autoscale.yaml` deployment
//! (simulated T4 GPUs serving ParticleNet, KEDA-style autoscaler on avg
//! queue latency). Prints the three Fig. 2 series as they evolve —
//! inference rate (blue), average latency (green) and GPU server count
//! (orange) — then renders ASCII timelines and writes the CSV.
//!
//! Run: `cargo run --release --example autoscale_demo`
//! (~3-4 minutes wall time; the experiment spans ~15 clock-minutes at
//! time_scale 4).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use supersonic::deployment::Deployment;
use supersonic::metrics::dashboard::Dashboard;
use supersonic::workload::{ClientPool, Schedule, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    println!("== SuperSONIC autoscaling demo (Fig. 2) ==\n");

    let d = Deployment::up_from_file(std::path::Path::new("configs/fig2-autoscale.yaml"))?;
    anyhow::ensure!(d.wait_ready(1, Duration::from_secs(60)), "instance not ready");
    println!("deployment ready at {} (time_scale {}x)\n", d.endpoint(), d.cfg.time_scale);

    // Live status line, printed every ~5 clock seconds.
    let stop = Arc::new(AtomicBool::new(false));
    let monitor = {
        let store = d.store.clone();
        let cluster = Arc::clone(&d.cluster);
        let clock = d.clock.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            println!(
                "{:>8} {:>9} {:>9} {:>12} {:>12}",
                "t(clock)", "servers", "desired", "latency(s)", "rate(inf/s)"
            );
            while !stop.load(Ordering::SeqCst) {
                let t = clock.now_secs();
                let lat = store.avg_latest_prefix("queue_latency_seconds").unwrap_or(0.0);
                let rate = store
                    .rate_over("exp_rows_total", t, Duration::from_secs(20))
                    .unwrap_or(0.0);
                println!(
                    "{:>8.0} {:>9} {:>9} {:>12.4} {:>12.1}",
                    t,
                    cluster.running(),
                    cluster.desired(),
                    lat,
                    rate
                );
                clock.sleep(Duration::from_secs(10));
            }
        })
    };

    // Aggregate row-rate series for the dashboard: sum instance counters.
    let aggregator = {
        let store = d.store.clone();
        let clock = d.clock.clone();
        let cluster = Arc::clone(&d.cluster);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                let t = clock.now_secs();
                // NB: the aggregate id must NOT share the per-instance
                // prefix it sums, or it would feed back into itself.
                store.push("exp_rows_total", t, store.sum_latest_prefix("inference_rows_total"));
                let rate = store
                    .rate_over("exp_rows_total", t, Duration::from_secs(20))
                    .unwrap_or(0.0);
                store.push("exp_rate", t, rate);
                store.push("gpu_servers", t, cluster.running() as f64);
                store.push(
                    "avg_queue_latency",
                    t,
                    store.avg_latest_prefix("queue_latency_seconds").unwrap_or(0.0),
                );
                clock.sleep(Duration::from_secs(2));
            }
        })
    };

    // The paper's workload: 1 -> 10 -> 1 perf_analyzer clients.
    let entry = d.repository.get("particlenet").unwrap();
    let mut spec = WorkloadSpec::new("particlenet", 16, entry.input_shape.clone());
    spec.think_time = Duration::from_millis(30);
    let schedule = Schedule::step_up_down(1, 10, Duration::from_secs(300));
    println!(
        "workload: 1 -> 10 -> 1 clients, {}s clock per phase\n",
        300
    );
    let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
    let report = pool.run_with(&schedule, |i, c| println!("---- phase {i}: {c} client(s)"));

    stop.store(true, Ordering::SeqCst);
    let _ = monitor.join();
    let _ = aggregator.join();

    // Fig. 2 panels.
    let dash = Dashboard::new()
        .with_size(100, 12)
        .panel("inference rate (rows/s)", "exp_rate")
        .panel("avg queue latency (s)", "avg_queue_latency")
        .panel("GPU servers", "gpu_servers");
    println!("\n{}", dash.render(&d.store));
    let csv = dash.to_csv(&d.store);
    let path = csv.save("fig2_autoscaling_demo")?;
    println!("series CSV written to {}", path.display());

    println!("\nper-phase summary:");
    for (i, p) in report.phases.iter().enumerate() {
        println!(
            "  phase {i}: {} clients, {:>7} ok, mean latency {:.3}s, p99 {:.3}s, {:.1} req/s",
            p.clients,
            p.ok,
            p.latency.mean(),
            p.latency.quantile(0.99),
            p.throughput()
        );
    }
    let peak = d.cluster.running();
    println!("\nservers at end (after scale-down): {peak}");
    d.down();
    Ok(())
}
