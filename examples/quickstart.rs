//! Quickstart — the end-to-end validation driver.
//!
//! Boots a full SuperSONIC deployment from `configs/quickstart.yaml`
//! (2 replicas, *real* PJRT execution of all three AOT-compiled models),
//! verifies numerics against the golden files over the network, then
//! serves a batched closed-loop workload and reports latency/throughput
//! per model. Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example quickstart`

use std::time::Duration;

use supersonic::deployment::Deployment;
use supersonic::rpc::client::RpcClient;
use supersonic::rpc::codec::Status;
use supersonic::runtime::golden;
use supersonic::workload::{ClientPool, Schedule, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    println!("== SuperSONIC quickstart ==\n");

    let t0 = std::time::Instant::now();
    let d = Deployment::up_from_file(std::path::Path::new("configs/quickstart.yaml"))?;
    anyhow::ensure!(d.wait_ready(2, Duration::from_secs(60)), "instances not ready");
    println!(
        "deployment '{}' ready in {:.2}s — endpoint {}, models: {}\n",
        d.cfg.name,
        t0.elapsed().as_secs_f64(),
        d.endpoint(),
        d.repository.names().join(", ")
    );

    // -- 1. numerics over the wire: golden inputs through gateway+batcher+PJRT
    println!("-- golden numerics over the network");
    let mut client = RpcClient::connect(&d.endpoint())?;
    for model in d.repository.names() {
        let dir = d.cfg.server.repository.join(&model);
        let g = golden::load(&dir.join("golden.b4.txt"))?;
        let resp = client.infer(&model, g.input.clone())?;
        anyhow::ensure!(resp.status == Status::Ok, "{model}: {}", resp.error);
        let diff = resp.output.max_abs_diff(&g.output)?;
        println!("   {model:<16} max_abs_diff vs JAX = {diff:.3e}  {}",
                 if diff < 1e-3 { "OK" } else { "FAIL" });
        anyhow::ensure!(diff < 1e-3, "{model}: numerics mismatch {diff}");
    }

    // -- 2. serve a real batched workload per model
    println!("\n-- closed-loop workload (4 clients x 10s per model, rows=4)");
    println!("{:<18} {:>8} {:>9} {:>10} {:>10} {:>10}", "model", "ok", "req/s", "p50 ms", "p99 ms", "mean ms");
    for model in d.repository.names() {
        let shape = d.repository.get(&model).unwrap().input_shape.clone();
        let spec = WorkloadSpec::new(&model, 4, shape);
        let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
        let report = pool.run(&Schedule::constant(4, Duration::from_secs(10)));
        anyhow::ensure!(report.total_errors == 0, "{model}: {} errors", report.total_errors);
        let p = &report.phases[0];
        println!(
            "{:<18} {:>8} {:>9.1} {:>10.2} {:>10.2} {:>10.2}",
            model,
            p.ok,
            p.throughput(),
            p.latency.quantile(0.5) * 1e3,
            p.latency.quantile(0.99) * 1e3,
            p.latency.mean() * 1e3,
        );
    }

    // -- 3. the §2.3 latency breakdown from tracing
    println!("\n-- latency breakdown by source (tracing, §2.3)");
    let tracer = d.tracer.clone();
    let mut client = RpcClient::connect(&d.endpoint())?;
    client.trace_id = tracer.new_trace();
    let shape = d.repository.get("particlenet").unwrap().input_shape.clone();
    let mut input_shape = vec![8];
    input_shape.extend_from_slice(&shape);
    let _ = client.infer("particlenet", supersonic::runtime::Tensor::zeros(input_shape))?;
    print!("{}", tracer.trace(client.trace_id).render());

    println!("\nquickstart complete.");
    d.down();
    Ok(())
}
