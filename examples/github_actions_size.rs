//! Tiny-footprint deployment — §3's "fits inside a single GitHub Actions
//! worker (4 CPU cores, 16 GB memory)" demonstration.
//!
//! Boots the `configs/github-actions.yaml` preset (1 replica, 1-GPU kind
//! cluster, 2 gateway threads), runs a generic client workflow, and
//! asserts the whole stack stays within a small resource envelope:
//! resident memory under 2 GiB and ~a dozen threads. Prints the envelope
//! so CI logs document the footprint.
//!
//! Run: `cargo run --release --example github_actions_size`

use std::time::Duration;

use supersonic::deployment::Deployment;
use supersonic::rpc::client::RpcClient;
use supersonic::rpc::codec::Status;
use supersonic::workload::{ClientPool, Schedule, WorkloadSpec};

/// Parse a field from /proc/self/status (Linux).
fn proc_status_kib(field: &str) -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(field) {
            return rest.trim().trim_end_matches(" kB").trim().parse().ok();
        }
    }
    None
}

fn main() -> anyhow::Result<()> {
    supersonic::util::logging::init();
    println!("== SuperSONIC on a GitHub-Actions-sized worker (§3) ==\n");

    let t0 = std::time::Instant::now();
    let d = Deployment::up_from_file(std::path::Path::new("configs/github-actions.yaml"))?;
    anyhow::ensure!(d.wait_ready(1, Duration::from_secs(30)), "instance not ready");
    let boot = t0.elapsed();

    // Generic client workflow: health probe + a few inferences + a short
    // closed-loop run (what the paper's CI smoke test exercises).
    let mut client = RpcClient::connect(&d.endpoint())?;
    anyhow::ensure!(client.health()?, "health probe failed");
    let entry = d.repository.get("icecube_cnn").unwrap();
    let mut shape = vec![2];
    shape.extend_from_slice(&entry.input_shape);
    let resp = client.infer("icecube_cnn", supersonic::runtime::Tensor::zeros(shape))?;
    anyhow::ensure!(resp.status == Status::Ok, "inference failed: {}", resp.error);

    let spec = WorkloadSpec::new("icecube_cnn", 2, entry.input_shape.clone());
    let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
    let report = pool.run(&Schedule::constant(2, Duration::from_secs(5)));
    anyhow::ensure!(report.total_ok > 0 && report.total_errors == 0, "workload failed");

    // Resource envelope.
    let rss_mib = proc_status_kib("VmRSS:").map(|k| k / 1024).unwrap_or(0);
    let threads = proc_status_kib("Threads:").unwrap_or(0);

    println!("boot time:        {:.2}s", boot.as_secs_f64());
    println!("requests served:  {} ({:.1} req/s)", report.total_ok, report.throughput());
    println!("resident memory:  {rss_mib} MiB");
    println!("threads:          {threads}");

    // The worker has 16 GB / 4 cores; leave a wide margin.
    anyhow::ensure!(rss_mib < 2048, "RSS {rss_mib} MiB exceeds 2 GiB envelope");
    anyhow::ensure!(threads < 64, "{threads} threads exceed envelope");
    println!("\nfits the 4-CPU / 16 GB GitHub Actions envelope. OK");
    d.down();
    Ok(())
}
