"""L2 correctness: model shapes, determinism, batch consistency.

These tests pin the contract the Rust coordinator relies on: fixed input
shapes per model, logits of the declared width, batch-row independence
(row i of a batched call equals a single-row call), and deterministic
parameters for a fixed seed (artifacts must be reproducible builds).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module", params=list(M.MODELS))
def spec(request):
    s = M.MODELS[request.param]
    params = s["init"](jax.random.PRNGKey(s["seed"]))
    return request.param, s, params


def _input(spec_entry, batch, seed=0):
    return jax.random.normal(
        jax.random.PRNGKey(seed), (batch, *spec_entry["input_shape"]), jnp.float32
    )


class TestShapes:
    def test_logit_shape(self, spec):
        name, s, params = spec
        x = _input(s, 3)
        y = s["apply"](params, x)
        assert y.shape == (3, s["output_dim"]), name

    def test_batch_one(self, spec):
        _, s, params = spec
        y = s["apply"](params, _input(s, 1))
        assert y.shape == (1, s["output_dim"])

    def test_finite_outputs(self, spec):
        _, s, params = spec
        y = np.asarray(s["apply"](params, _input(s, 4, seed=7)))
        assert np.isfinite(y).all()


class TestDeterminism:
    def test_params_deterministic(self, spec):
        name, s, _ = spec
        p1 = s["init"](jax.random.PRNGKey(s["seed"]))
        p2 = s["init"](jax.random.PRNGKey(s["seed"]))
        for k in p1:
            np.testing.assert_array_equal(np.asarray(p1[k]), np.asarray(p2[k]))

    def test_apply_deterministic(self, spec):
        _, s, params = spec
        x = _input(s, 2, seed=3)
        y1 = np.asarray(s["apply"](params, x))
        y2 = np.asarray(s["apply"](params, x))
        np.testing.assert_array_equal(y1, y2)


class TestBatchConsistency:
    def test_rows_independent(self, spec):
        """Batched inference must equal per-row inference (the dynamic
        batcher on the Rust side depends on this)."""
        name, s, params = spec
        x = _input(s, 4, seed=11)
        batched = np.asarray(s["apply"](params, x))
        for i in range(4):
            single = np.asarray(s["apply"](params, x[i : i + 1]))
            np.testing.assert_allclose(
                batched[i], single[0], rtol=1e-4, atol=1e-5,
                err_msg=f"{name} row {i}",
            )

    def test_padding_rows_do_not_affect_real_rows(self, spec):
        """Zero-padding extra batch rows (what the batcher does to hit a
        compiled batch size) must not change the real rows' outputs."""
        name, s, params = spec
        x = _input(s, 2, seed=13)
        padded = jnp.concatenate([x, jnp.zeros((2, *s["input_shape"]), jnp.float32)])
        y_real = np.asarray(s["apply"](params, x))
        y_padded = np.asarray(s["apply"](params, padded))[:2]
        np.testing.assert_allclose(y_real, y_padded, rtol=1e-4, atol=1e-5)


class TestParticleNetSpecifics:
    def test_param_count_reasonable(self):
        s = M.MODELS["particlenet"]
        params = s["init"](jax.random.PRNGKey(s["seed"]))
        n = M.param_count(params)
        # ParticleNet-Lite scale: tens of thousands of parameters.
        assert 10_000 < n < 500_000, n

    def test_permutation_invariance(self):
        """A point-cloud GNN with symmetric aggregation is invariant to
        particle ordering."""
        s = M.MODELS["particlenet"]
        params = s["init"](jax.random.PRNGKey(s["seed"]))
        x = _input(s, 1, seed=17)
        perm = jax.random.permutation(jax.random.PRNGKey(0), x.shape[1])
        y1 = np.asarray(s["apply"](params, x))
        y2 = np.asarray(s["apply"](params, x[:, perm, :]))
        np.testing.assert_allclose(y1, y2, rtol=1e-3, atol=1e-4)


class TestTransformerSpecifics:
    def test_token_permutation_equivariance_of_pool(self):
        # Mean-pooled transformer without positional encodings is
        # permutation-invariant; this documents the architecture choice.
        s = M.MODELS["cms_transformer"]
        params = s["init"](jax.random.PRNGKey(s["seed"]))
        x = _input(s, 1, seed=19)
        perm = jax.random.permutation(jax.random.PRNGKey(1), x.shape[1])
        y1 = np.asarray(s["apply"](params, x))
        y2 = np.asarray(s["apply"](params, x[:, perm, :]))
        np.testing.assert_allclose(y1, y2, rtol=1e-3, atol=1e-4)
