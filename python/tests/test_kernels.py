"""L1 correctness: Pallas kernels vs pure-jnp references.

The hypothesis sweeps are the core correctness signal for the kernels —
they vary N (including non-multiples of the block size), feature widths,
K, channel widths and the block parameter itself.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import edgeconv, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# pairwise_sq_dists
# ---------------------------------------------------------------------------


class TestPairwise:
    def test_matches_ref_basic(self):
        x = _rand(0, (64, 7))
        got = edgeconv.pairwise_sq_dists(x)
        want = ref.pairwise_sq_dists_ref(x)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_diagonal_is_zero(self):
        x = _rand(1, (32, 3))
        d = edgeconv.pairwise_sq_dists(x)
        np.testing.assert_allclose(np.diag(np.asarray(d)), 0.0, atol=1e-4)

    def test_symmetric(self):
        x = _rand(2, (48, 5))
        d = np.asarray(edgeconv.pairwise_sq_dists(x))
        np.testing.assert_allclose(d, d.T, rtol=1e-5, atol=1e-5)

    def test_nonnegative(self):
        x = _rand(3, (40, 4), scale=10.0)
        d = np.asarray(edgeconv.pairwise_sq_dists(x))
        assert (d >= 0.0).all()

    def test_non_multiple_of_block(self):
        # N=50 is not a multiple of the default 32-block: exercises padding.
        x = _rand(4, (50, 7))
        got = edgeconv.pairwise_sq_dists(x)
        want = ref.pairwise_sq_dists_ref(x)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_known_values(self):
        x = jnp.array([[0.0, 0.0], [3.0, 4.0]], jnp.float32)
        d = np.asarray(edgeconv.pairwise_sq_dists(x))
        np.testing.assert_allclose(d, [[0.0, 25.0], [25.0, 0.0]], atol=1e-5)

    @given(
        n=st.integers(min_value=2, max_value=96),
        c=st.integers(min_value=1, max_value=16),
        block=st.sampled_from([8, 16, 32]),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_matches_ref_sweep(self, n, c, block, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (n, c), jnp.float32)
        got = edgeconv.pairwise_sq_dists(x, block=block)
        want = ref.pairwise_sq_dists_ref(x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# edge_mlp_aggregate
# ---------------------------------------------------------------------------


def _mlp_params(f2, c1, c2, c3, seed=0):
    return (
        _rand(seed + 1, (f2, c1), 0.3),
        _rand(seed + 2, (c1,), 0.1),
        _rand(seed + 3, (c1, c2), 0.3),
        _rand(seed + 4, (c2,), 0.1),
        _rand(seed + 5, (c2, c3), 0.3),
        _rand(seed + 6, (c3,), 0.1),
    )


class TestEdgeMlpAggregate:
    def test_matches_ref_basic(self):
        e = _rand(10, (64, 16, 14))
        ps = _mlp_params(14, 32, 32, 32)
        got = edgeconv.edge_mlp_aggregate(e, *ps)
        want = ref.edge_mlp_aggregate_ref(e, *ps)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_output_nonnegative(self):
        # ReLU final layer + max aggregation => nonnegative outputs.
        e = _rand(11, (32, 8, 6))
        ps = _mlp_params(6, 8, 8, 4)
        out = np.asarray(edgeconv.edge_mlp_aggregate(e, *ps))
        assert (out >= 0.0).all()

    def test_permutation_invariant_in_k(self):
        # Max aggregation is invariant to neighbor ordering.
        e = _rand(12, (16, 8, 6))
        ps = _mlp_params(6, 8, 8, 4)
        out1 = edgeconv.edge_mlp_aggregate(e, *ps)
        perm = jax.random.permutation(jax.random.PRNGKey(0), 8)
        out2 = edgeconv.edge_mlp_aggregate(e[:, perm, :], *ps)
        np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-6)

    def test_non_multiple_of_block(self):
        e = _rand(13, (37, 4, 6))
        ps = _mlp_params(6, 8, 8, 4)
        got = edgeconv.edge_mlp_aggregate(e, *ps)
        want = ref.edge_mlp_aggregate_ref(e, *ps)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    @given(
        n=st.integers(min_value=1, max_value=80),
        k=st.sampled_from([2, 4, 8, 16]),
        f=st.integers(min_value=1, max_value=8),
        c=st.sampled_from([4, 8, 16]),
        block=st.sampled_from([8, 16, 32]),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_matches_ref_sweep(self, n, k, f, c, block, seed):
        key = jax.random.PRNGKey(seed)
        e = jax.random.normal(key, (n, k, 2 * f), jnp.float32)
        ps = _mlp_params(2 * f, c, c, c, seed=seed % 1000)
        got = edgeconv.edge_mlp_aggregate(e, *ps, block=block)
        want = ref.edge_mlp_aggregate_ref(e, *ps)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_zero_input(self):
        e = jnp.zeros((8, 4, 6), jnp.float32)
        ps = _mlp_params(6, 8, 8, 4)
        got = edgeconv.edge_mlp_aggregate(e, *ps)
        want = ref.edge_mlp_aggregate_ref(e, *ps)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# dtype coverage: kernels promise f32; bf16 inputs should be accepted by the
# reference path at reduced tolerance (documents numeric behaviour).
# ---------------------------------------------------------------------------


class TestDtypes:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_pairwise_dtypes(self, dtype):
        x = _rand(20, (24, 4)).astype(dtype)
        got = np.asarray(edgeconv.pairwise_sq_dists(x.astype(jnp.float32)))
        want = np.asarray(ref.pairwise_sq_dists_ref(x)).astype(np.float32)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)
