"""L1 correctness: fused attention kernel vs pure-jnp reference."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _qkv(seed, h, t, dh, scale=1.0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (h, t, dh), jnp.float32) * scale
    k = jax.random.normal(k2, (h, t, dh), jnp.float32) * scale
    v = jax.random.normal(k3, (h, t, dh), jnp.float32) * scale
    return q, k, v


class TestFusedAttention:
    def test_matches_ref_basic(self):
        q, k, v = _qkv(0, 4, 32, 8)
        got = attention.fused_attention(q, k, v)
        want = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_non_multiple_of_block(self):
        # T = 19 with block_q = 8 exercises query padding + key masking.
        q, k, v = _qkv(1, 2, 19, 8)
        got = attention.fused_attention(q, k, v, block_q=8)
        want = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_rows_are_convex_combinations(self):
        # Attention output rows live in the convex hull of V rows.
        q, k, v = _qkv(2, 2, 16, 4)
        out = np.asarray(attention.fused_attention(q, k, v))
        vmin = np.asarray(v).min(axis=1, keepdims=True)
        vmax = np.asarray(v).max(axis=1, keepdims=True)
        assert (out >= vmin - 1e-5).all() and (out <= vmax + 1e-5).all()

    def test_uniform_when_queries_zero(self):
        # q = 0 -> uniform attention -> every output row is mean(V).
        h, t, dh = 2, 12, 4
        _, k, v = _qkv(3, h, t, dh)
        q = jnp.zeros((h, t, dh), jnp.float32)
        out = attention.fused_attention(q, k, v)
        want = jnp.broadcast_to(jnp.mean(v, axis=1, keepdims=True), (h, t, dh))
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    def test_large_logits_stable(self):
        # The max-subtraction softmax must survive +/- 60 logits.
        q, k, v = _qkv(4, 2, 16, 8, scale=10.0)
        out = np.asarray(attention.fused_attention(q, k, v))
        assert np.isfinite(out).all()

    def test_permuting_keys_and_values_is_noop(self):
        # Softmax-weighted sum is invariant to a joint permutation of K/V.
        q, k, v = _qkv(5, 2, 16, 4)
        perm = jax.random.permutation(jax.random.PRNGKey(9), 16)
        base = attention.fused_attention(q, k, v)
        shuf = attention.fused_attention(q, k[:, perm, :], v[:, perm, :])
        np.testing.assert_allclose(base, shuf, rtol=1e-5, atol=1e-5)

    def test_under_vmap_matches_ref(self):
        # The transformer calls the kernel under jax.vmap over the batch.
        b, h, t, dh = 3, 4, 32, 8
        keys = jax.random.split(jax.random.PRNGKey(6), 3)
        q = jax.random.normal(keys[0], (b, h, t, dh), jnp.float32)
        k = jax.random.normal(keys[1], (b, h, t, dh), jnp.float32)
        v = jax.random.normal(keys[2], (b, h, t, dh), jnp.float32)
        got = jax.vmap(attention.fused_attention)(q, k, v)
        want = jax.vmap(ref.attention_ref)(q, k, v)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @given(
        h=st.sampled_from([1, 2, 4]),
        t=st.integers(min_value=2, max_value=48),
        dh=st.sampled_from([2, 4, 8, 16]),
        block_q=st.sampled_from([4, 8, 16]),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    def test_matches_ref_sweep(self, h, t, dh, block_q, seed):
        q, k, v = _qkv(seed, h, t, dh)
        got = attention.fused_attention(q, k, v, block_q=block_q)
        want = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
