"""AOT pipeline tests: HLO text compatibility and golden-file format.

The interchange constraints these tests pin down were discovered the hard
way (see aot.py docstring): the 0.5.1 HLO text parser on the Rust side
rejects `topk` instructions and new metadata attributes, and silently
mis-parses elided `{...}` constants. A regression in any of these would
produce artifacts that either fail to load or — worse — load and compute
garbage.
"""

import os
import re
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def tiny_artifact_dir():
    """Compile the CNN (fastest model) at batch sizes 1 and 2."""
    with tempfile.TemporaryDirectory() as tmp:
        aot.compile_model("icecube_cnn", tmp, batch_sizes=(1, 2))
        yield tmp


class TestHloText:
    def test_no_elided_constants(self, tiny_artifact_dir):
        """`{...}` in the text means the printer elided a weight constant —
        the 0.5.1 parser accepts it and fills garbage. Must never appear."""
        p = os.path.join(tiny_artifact_dir, "icecube_cnn", "model.b1.hlo.txt")
        text = open(p).read()
        assert "{...}" not in text

    @pytest.mark.parametrize("name", sorted(M.MODELS))
    def test_no_unparseable_instructions(self, name):
        """jax>=0.8 lowers lax.top_k to a `topk` HLO op the old parser
        rejects, and real-TPU Pallas lowering emits Mosaic custom-calls;
        every model (all three call Pallas kernels) must lower to classic
        parseable HLO."""
        spec = M.MODELS[name]
        params = spec["init"](jax.random.PRNGKey(spec["seed"]))
        fwd = lambda x: (spec["apply"](params, x),)
        x_spec = jax.ShapeDtypeStruct((1, *spec["input_shape"]), jnp.float32)
        text = aot.to_hlo_text(jax.jit(fwd).lower(x_spec))
        assert not re.search(r"\btopk\(", text), "topk instruction in HLO"
        assert "custom-call" not in text, "custom-call in HLO (Mosaic leak?)"
        assert "{...}" not in text, "elided constant in HLO"

    def test_no_new_metadata_attrs(self, tiny_artifact_dir):
        p = os.path.join(tiny_artifact_dir, "icecube_cnn", "model.b2.hlo.txt")
        text = open(p).read()
        assert "source_end_line" not in text

    def test_entry_is_tuple(self, tiny_artifact_dir):
        """Artifacts are lowered with return_tuple=True; Rust unwraps a
        1-tuple."""
        p = os.path.join(tiny_artifact_dir, "icecube_cnn", "model.b1.hlo.txt")
        text = open(p).read()
        assert re.search(r"ROOT .* tuple\(", text)


class TestRepositoryLayout:
    def test_config_yaml_written(self, tiny_artifact_dir):
        cfg = open(
            os.path.join(tiny_artifact_dir, "icecube_cnn", "config.yaml")
        ).read()
        assert "name: icecube_cnn" in cfg
        assert "batch_sizes: [1, 2]" in cfg
        assert "max_batch_size: 2" in cfg

    def test_goldens_written_and_parse(self, tiny_artifact_dir):
        for bs in (1, 2):
            p = os.path.join(tiny_artifact_dir, "icecube_cnn", f"golden.b{bs}.txt")
            lines = open(p).read().strip().split("\n")
            assert len(lines) == 4
            header = lines[0].split()
            assert header[0] == "input"
            dims = [int(d) for d in header[1:]]
            assert dims[0] == bs
            n = int(np.prod(dims))
            assert len(lines[1].split()) == n

    def test_golden_roundtrip_matches_model(self, tiny_artifact_dir):
        """Re-evaluating the model on the stored golden input must give the
        stored golden output (pin against drift in param init)."""
        spec = M.MODELS["icecube_cnn"]
        params = spec["init"](jax.random.PRNGKey(spec["seed"]))
        p = os.path.join(tiny_artifact_dir, "icecube_cnn", "golden.b1.txt")
        lines = open(p).read().strip().split("\n")
        in_dims = [int(d) for d in lines[0].split()[1:]]
        x = jnp.asarray(
            np.array([float(v) for v in lines[1].split()], np.float32).reshape(in_dims)
        )
        out_dims = [int(d) for d in lines[2].split()[1:]]
        want = np.array([float(v) for v in lines[3].split()], np.float32).reshape(out_dims)
        got = np.asarray(spec["apply"](params, x))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


class TestBatchParsing:
    def test_artifact_name_scheme(self):
        # Mirrors runtime::parse_artifact_batch on the Rust side.
        assert aot.BATCH_SIZES == (1, 2, 4, 8, 16)
