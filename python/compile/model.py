"""Layer-2 JAX models served by the SuperSONIC stack.

Three models, mirroring the client workloads named in the paper (§3):

* ``particlenet`` — a ParticleNet-style EdgeConv GNN for jet tagging (the
  model used for the paper's Fig. 2/3 autoscaling study, CMS workload).
  Its FLOP-heavy inner loops are the Pallas kernels in
  ``kernels/edgeconv.py``.
* ``icecube_cnn`` — a small 2D CNN standing in for the IceCube/LIGO
  convolutional workloads.
* ``cms_transformer`` — a small transformer standing in for the CMS
  transformer-architecture workloads.

Each model is a pure function ``apply(params, x) -> logits`` plus an
``init(key)`` that builds deterministic parameters. ``aot.py`` closes the
apply over the params and lowers one HLO artifact per (model, batch size),
so the served artifact is self-contained (weights baked in), exactly like a
model checkout in a Triton model repository.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels import attention, edgeconv

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# shared initializers
# ---------------------------------------------------------------------------


def _dense_init(key, fan_in: int, fan_out: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = math.sqrt(2.0 / fan_in)
    w = jax.random.normal(key, (fan_in, fan_out), jnp.float32) * scale
    return w, jnp.zeros((fan_out,), jnp.float32)


# ---------------------------------------------------------------------------
# ParticleNet-style EdgeConv GNN
# ---------------------------------------------------------------------------

#: input point cloud: N particles x F kinematic features (pt, eta, phi, E, ...)
PARTICLENET_POINTS = 64
PARTICLENET_FEATURES = 7
PARTICLENET_K = 16
#: EdgeConv block channel plans, ParticleNet-Lite-ish.
PARTICLENET_BLOCKS = ((32, 32, 32), (64, 64, 64))
PARTICLENET_HIDDEN = 64
PARTICLENET_CLASSES = 2


def particlenet_init(key) -> Params:
    params: Params = {}
    f = PARTICLENET_FEATURES
    keys = jax.random.split(key, 16)
    ki = iter(keys)
    for bi, chans in enumerate(PARTICLENET_BLOCKS):
        fin = f
        for li, c in enumerate(chans):
            w, b = _dense_init(next(ki), 2 * f if li == 0 else fin, c)
            params[f"b{bi}_w{li}"] = w
            params[f"b{bi}_b{li}"] = b
            fin = c
        # shortcut projection x_i -> C3 (ParticleNet's residual conv)
        w, b = _dense_init(next(ki), f, chans[-1])
        params[f"b{bi}_ws"] = w
        params[f"b{bi}_bs"] = b
        f = chans[-1]
    w, b = _dense_init(next(ki), f, PARTICLENET_HIDDEN)
    params["fc_w"], params["fc_b"] = w, b
    w, b = _dense_init(next(ki), PARTICLENET_HIDDEN, PARTICLENET_CLASSES)
    params["out_w"], params["out_b"] = w, b
    return params


def _knn_indices(coords: jnp.ndarray, k: int) -> jnp.ndarray:
    """(N, K) indices of each point's k nearest neighbors (excluding self).

    Distances come from the Pallas pairwise kernel; selection stays in XLA —
    see DESIGN.md §Hardware-Adaptation. Selection uses argsort rather than
    ``lax.top_k``: jax >= 0.8 lowers top_k to the dedicated ``topk`` HLO
    instruction, which the xla_extension 0.5.1 text parser on the Rust side
    does not know; argsort lowers to the classic ``sort`` instruction that
    round-trips cleanly.
    """
    d = edgeconv.pairwise_sq_dists(coords)
    n = d.shape[0]
    d = d + jnp.eye(n, dtype=d.dtype) * 1e9  # exclude self
    idx = jnp.argsort(d, axis=-1)[:, :k]
    return idx


def _edgeconv_block(x: jnp.ndarray, coords: jnp.ndarray, params: Params, bi: int) -> jnp.ndarray:
    """One EdgeConv block over a single point cloud.

    x: (N, F) features; coords: (N, C) coordinates used for kNN.
    Returns (N, C3).
    """
    idx = _knn_indices(coords, PARTICLENET_K)  # (N, K)
    nbrs = jnp.take(x, idx, axis=0)  # (N, K, F) gather stays in XLA
    center = x[:, None, :]
    edge = jnp.concatenate(
        [jnp.broadcast_to(center, nbrs.shape), nbrs - center], axis=-1
    )  # (N, K, 2F)
    agg = edgeconv.edge_mlp_aggregate(
        edge,
        params[f"b{bi}_w0"],
        params[f"b{bi}_b0"],
        params[f"b{bi}_w1"],
        params[f"b{bi}_b1"],
        params[f"b{bi}_w2"],
        params[f"b{bi}_b2"],
    )  # (N, C3)
    shortcut = x @ params[f"b{bi}_ws"] + params[f"b{bi}_bs"]
    return jnp.maximum(agg + shortcut, 0.0)


def particlenet_apply(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Forward pass.

    Args:
      params: see ``particlenet_init``.
      x: (B, N, F) float32 batch of point clouds.
    Returns:
      (B, CLASSES) float32 logits.
    """

    def single(cloud: jnp.ndarray) -> jnp.ndarray:
        coords = cloud[:, :3]  # (eta, phi, log pt) style coordinates
        h = _edgeconv_block(cloud, coords, params, 0)
        # second block: kNN in learned feature space, like ParticleNet
        h = _edgeconv_block(h, h[:, :3], params, 1)
        pooled = jnp.mean(h, axis=0)  # global average pool
        hid = jnp.maximum(pooled @ params["fc_w"] + params["fc_b"], 0.0)
        return hid @ params["out_w"] + params["out_b"]

    return jax.vmap(single)(x)


# ---------------------------------------------------------------------------
# IceCube/LIGO-style CNN
# ---------------------------------------------------------------------------

CNN_HW = 16
CNN_CHANNELS = 3
CNN_CLASSES = 3


def cnn_init(key) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params: Params = {}
    params["c1_w"] = jax.random.normal(k1, (3, 3, CNN_CHANNELS, 16), jnp.float32) * 0.2
    params["c1_b"] = jnp.zeros((16,), jnp.float32)
    params["c2_w"] = jax.random.normal(k2, (3, 3, 16, 32), jnp.float32) * 0.1
    params["c2_b"] = jnp.zeros((32,), jnp.float32)
    flat = (CNN_HW // 4) * (CNN_HW // 4) * 32
    params["fc_w"], params["fc_b"] = _dense_init(k3, flat, 64)
    params["out_w"], params["out_b"] = _dense_init(k4, 64, CNN_CLASSES)
    return params


def _conv2d(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_apply(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """(B, H, W, C) -> (B, CLASSES) logits."""
    h = jnp.maximum(_conv2d(x, params["c1_w"]) + params["c1_b"], 0.0)
    h = _maxpool2(h)
    h = jnp.maximum(_conv2d(h, params["c2_w"]) + params["c2_b"], 0.0)
    h = _maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jnp.maximum(h @ params["fc_w"] + params["fc_b"], 0.0)
    return h @ params["out_w"] + params["out_b"]


# ---------------------------------------------------------------------------
# CMS-style transformer
# ---------------------------------------------------------------------------

TFM_TOKENS = 32
TFM_DIM = 32
TFM_HEADS = 4
TFM_LAYERS = 2
TFM_FF = 64
TFM_CLASSES = 2


def transformer_init(key) -> Params:
    params: Params = {}
    keys = jax.random.split(key, TFM_LAYERS * 6 + 2)
    ki = iter(keys)
    for li in range(TFM_LAYERS):
        for name in ("q", "k", "v", "o"):
            w, b = _dense_init(next(ki), TFM_DIM, TFM_DIM)
            params[f"l{li}_{name}_w"], params[f"l{li}_{name}_b"] = w, b
        w, b = _dense_init(next(ki), TFM_DIM, TFM_FF)
        params[f"l{li}_ff1_w"], params[f"l{li}_ff1_b"] = w, b
        w, b = _dense_init(next(ki), TFM_FF, TFM_DIM)
        params[f"l{li}_ff2_w"], params[f"l{li}_ff2_b"] = w, b
    w, b = _dense_init(next(ki), TFM_DIM, TFM_CLASSES)
    params["out_w"], params["out_b"] = w, b
    return params


def _layernorm(x: jnp.ndarray) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5)


def _attention(x: jnp.ndarray, params: Params, li: int) -> jnp.ndarray:
    b, t, d = x.shape
    hd = d // TFM_HEADS

    def proj(name):
        y = x @ params[f"l{li}_{name}_w"] + params[f"l{li}_{name}_b"]
        return y.reshape(b, t, TFM_HEADS, hd).transpose(0, 2, 1, 3)

    q, k, v = proj("q"), proj("k"), proj("v")  # (B, H, T, Dh)
    # The FLOP hot-spot runs in the fused Pallas kernel (scores never
    # reach HBM); vmap over the batch like the EdgeConv kernels.
    out = jax.vmap(attention.fused_attention)(q, k, v)  # (B, H, T, Dh)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ params[f"l{li}_o_w"] + params[f"l{li}_o_b"]


def transformer_apply(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """(B, T, D) -> (B, CLASSES) logits."""
    h = x
    for li in range(TFM_LAYERS):
        h = h + _attention(_layernorm(h), params, li)
        ff = jnp.maximum(
            _layernorm(h) @ params[f"l{li}_ff1_w"] + params[f"l{li}_ff1_b"], 0.0
        )
        h = h + ff @ params[f"l{li}_ff2_w"] + params[f"l{li}_ff2_b"]
    pooled = jnp.mean(h, axis=1)
    return pooled @ params["out_w"] + params["out_b"]


# ---------------------------------------------------------------------------
# registry used by aot.py and the tests
# ---------------------------------------------------------------------------

MODELS = {
    "particlenet": {
        "init": particlenet_init,
        "apply": particlenet_apply,
        "input_shape": (PARTICLENET_POINTS, PARTICLENET_FEATURES),
        "output_dim": PARTICLENET_CLASSES,
        "seed": 42,
    },
    "icecube_cnn": {
        "init": cnn_init,
        "apply": cnn_apply,
        "input_shape": (CNN_HW, CNN_HW, CNN_CHANNELS),
        "output_dim": CNN_CLASSES,
        "seed": 43,
    },
    "cms_transformer": {
        "init": transformer_init,
        "apply": transformer_apply,
        "input_shape": (TFM_TOKENS, TFM_DIM),
        "output_dim": TFM_CLASSES,
        "seed": 44,
    },
}


def param_count(params: Params) -> int:
    return sum(int(p.size) for p in params.values())
