"""Pure-jnp reference implementations (correctness oracles) for the Pallas kernels.

Every Pallas kernel in this package has a reference twin here, written in
straightforward jax.numpy with no tiling or fusion tricks. The pytest suite
(`python/tests/test_kernels.py`) asserts allclose between kernel and
reference across a hypothesis-driven sweep of shapes and dtypes.

The two kernels cover the FLOP-heavy pieces of ParticleNet's EdgeConv:

* pairwise squared distances between point-cloud coordinates (feeds kNN), and
* the fused edge-MLP + max-aggregation over each point's K neighbors.

kNN selection itself stays at L2 (`jax.lax.top_k`) — see DESIGN.md
§Hardware-Adaptation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_sq_dists_ref(coords: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distance matrix for a point cloud.

    Args:
      coords: (N, C) point coordinates.
    Returns:
      (N, N) matrix D with D[i, j] = ||coords[i] - coords[j]||^2.
    """
    sq = jnp.sum(coords * coords, axis=-1)  # (N,)
    inner = coords @ coords.T  # (N, N)
    d = sq[:, None] + sq[None, :] - 2.0 * inner
    # Numerical noise can push diagonal/near-duplicate entries slightly
    # negative; clamp like the kernel does.
    return jnp.maximum(d, 0.0)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Plain multi-head scaled-dot-product attention.

    Args:
      q, k, v: (H, T, Dh) per-head projections.
    Returns:
      (H, T, Dh): softmax(q @ k.T / sqrt(Dh)) @ v per head.
    """
    dh = q.shape[-1]
    scores = jnp.einsum("htd,hsd->hts", q, k) / jnp.sqrt(jnp.float32(dh))
    attn = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hts,hsd->htd", attn, v)


def edge_mlp_aggregate_ref(
    edge_feats: jnp.ndarray,
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
    w3: jnp.ndarray,
    b3: jnp.ndarray,
) -> jnp.ndarray:
    """Three-layer edge MLP followed by max-aggregation over neighbors.

    This is the EdgeConv inner loop: for every (point, neighbor) pair we run
    a shared MLP over the edge feature vector, then max-reduce over the K
    neighbors of each point.

    Args:
      edge_feats: (N, K, 2F) edge features [x_i ; x_j - x_i].
      w1: (2F, C1), b1: (C1,)
      w2: (C1, C2), b2: (C2,)
      w3: (C2, C3), b3: (C3,)
    Returns:
      (N, C3) aggregated features: max_k relu(mlp(edge_feats[:, k, :])).
    """
    h = jnp.maximum(edge_feats @ w1 + b1, 0.0)
    h = jnp.maximum(h @ w2 + b2, 0.0)
    h = jnp.maximum(h @ w3 + b3, 0.0)
    return jnp.max(h, axis=1)
