"""Pallas kernels for ParticleNet's EdgeConv hot-spot.

Two kernels, both lowered with ``interpret=True`` so they become plain HLO
that any PJRT backend (including the Rust CPU client on the request path)
can execute. Real-TPU lowering would emit a Mosaic custom-call; on this
testbed the interpret path is the correctness target and the TPU mapping is
documented in DESIGN.md §Hardware-Adaptation.

Hardware adaptation summary (GPU paper -> TPU kernel):

* ``pairwise_sq_dists`` tiles the (N, N) distance matrix into
  (BLK_I, BLK_J) VMEM-resident blocks; the cross term is a
  (BLK_I, C) x (C, BLK_J) matmul that feeds the MXU, while the squared
  norms ride along as rank-1 broadcasts. A CUDA implementation would give
  each threadblock an output tile and stage coords through shared memory;
  BlockSpec expresses the same HBM->VMEM schedule declaratively.

* ``edge_mlp_aggregate`` fuses the three-layer edge MLP with the max
  reduction over the K neighbors so the (N, K, C) activations never leave
  VMEM / never hit HBM. Each grid step owns a block of BLK points: the
  (BLK*K, 2F) edge-feature tile is pushed through three MXU matmuls and
  max-reduced over K in-register. The CUDA version materializes the edge
  activations in global memory between conv layers unless hand-fused; the
  Pallas version makes the fusion structural.

VMEM footprint (defaults BLK=32, K=16, F<=64, C<=128, f32):
  edge tile 32*16*128*4 = 256 KiB, weights < 70 KiB, activations
  2 x 32*16*128*4 = 512 KiB -> well under the ~16 MiB VMEM budget; BLK
  could grow 8x on real hardware, see kernels/README.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# Kernel 1: pairwise squared distances
# ---------------------------------------------------------------------------


def _pairwise_kernel(xi_ref, xj_ref, o_ref):
    """One (BLK_I, BLK_J) tile of the distance matrix.

    xi_ref: (BLK_I, C) rows of the tile.
    xj_ref: (BLK_J, C) cols of the tile.
    o_ref:  (BLK_I, BLK_J) output tile.
    """
    xi = xi_ref[...]
    xj = xj_ref[...]
    sq_i = jnp.sum(xi * xi, axis=-1)  # (BLK_I,)
    sq_j = jnp.sum(xj * xj, axis=-1)  # (BLK_J,)
    # MXU-shaped cross term.
    inner = jnp.dot(xi, xj.T, preferred_element_type=jnp.float32)
    d = sq_i[:, None] + sq_j[None, :] - 2.0 * inner
    o_ref[...] = jnp.maximum(d, 0.0)


@functools.partial(jax.jit, static_argnames=("block",))
def pairwise_sq_dists(coords: jnp.ndarray, *, block: int = 32) -> jnp.ndarray:
    """Pallas pairwise squared-distance matrix.

    Args:
      coords: (N, C) float32 point coordinates. N need not be a multiple of
        ``block``; inputs are zero-padded and the pad region is sliced away.
      block: tile edge for the (N, N) output grid.
    Returns:
      (N, N) float32 squared distances, clamped at zero.
    """
    n, c = coords.shape
    np_ = _ceil_to(n, block)
    padded = jnp.zeros((np_, c), coords.dtype).at[:n].set(coords)

    grid = (np_ // block, np_ // block)
    out = pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, c), lambda i, j: (i, 0)),
            pl.BlockSpec((block, c), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, np_), jnp.float32),
        interpret=True,
    )(padded, padded)
    return out[:n, :n]


# ---------------------------------------------------------------------------
# Kernel 2: fused edge-MLP + max aggregation
# ---------------------------------------------------------------------------


def _edge_mlp_kernel(e_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, o_ref):
    """One block of BLK points: 3-layer MLP over (BLK*K, 2F), max over K.

    e_ref: (BLK, K, 2F) edge-feature tile.
    o_ref: (BLK, C3) aggregated output tile.
    """
    blk, k, f2 = e_ref.shape
    e = e_ref[...].reshape(blk * k, f2)
    h = jnp.maximum(
        jnp.dot(e, w1_ref[...], preferred_element_type=jnp.float32) + b1_ref[...],
        0.0,
    )
    h = jnp.maximum(
        jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32) + b2_ref[...],
        0.0,
    )
    h = jnp.maximum(
        jnp.dot(h, w3_ref[...], preferred_element_type=jnp.float32) + b3_ref[...],
        0.0,
    )
    o_ref[...] = jnp.max(h.reshape(blk, k, -1), axis=1)


@functools.partial(jax.jit, static_argnames=("block",))
def edge_mlp_aggregate(
    edge_feats: jnp.ndarray,
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
    w3: jnp.ndarray,
    b3: jnp.ndarray,
    *,
    block: int = 32,
) -> jnp.ndarray:
    """Fused EdgeConv MLP + neighbor max-aggregation.

    Args:
      edge_feats: (N, K, 2F) float32 edge features [x_i ; x_j - x_i].
      w1/b1, w2/b2, w3/b3: MLP parameters, (2F,C1)/(C1,), (C1,C2)/(C2,),
        (C2,C3)/(C3,).
      block: points per grid step.
    Returns:
      (N, C3) float32, max over the K axis of relu(mlp(edge_feats)).
    """
    n, k, f2 = edge_feats.shape
    c3 = w3.shape[1]
    np_ = _ceil_to(n, block)
    padded = jnp.zeros((np_, k, f2), edge_feats.dtype).at[:n].set(edge_feats)

    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    out = pl.pallas_call(
        _edge_mlp_kernel,
        grid=(np_ // block,),
        in_specs=[
            pl.BlockSpec((block, k, f2), lambda i: (i, 0, 0)),
            full(w1.shape),
            full(b1.shape),
            full(w2.shape),
            full(b2.shape),
            full(w3.shape),
            full(b3.shape),
        ],
        out_specs=pl.BlockSpec((block, c3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, c3), jnp.float32),
        interpret=True,
    )(padded, w1, b1, w2, b2, w3, b3)
    return out[:n]
