"""Pallas kernel for the CMS transformer's attention hot-spot.

Fused scaled-dot-product attention: ``softmax(q @ k.T / sqrt(dh)) @ v``
computed tile-by-tile so the (T, T) score matrix never materializes in
HBM — the FlashAttention insight, restructured for the TPU memory model
(see DESIGN.md §Hardware-Adaptation):

* the grid walks (head, query-block); each step owns a (BLK_Q, Dh) query
  tile plus the head's full (T, Dh) key/value panels in VMEM — for the
  sequence lengths the CMS workloads use (tens to a few hundred tokens)
  the panels fit comfortably, so no online-softmax accumulator loop is
  needed (that variant only pays off once T*Dh outgrows VMEM);
* scores (BLK_Q, T) are computed on the MXU, softmax-normalized with the
  max-subtraction trick in-register (VPU), and immediately contracted
  against V on the MXU again — one HBM read per operand tile, one HBM
  write of the (BLK_Q, Dh) output, zero score traffic.

A CUDA implementation stages K/V panels through shared memory per
threadblock and keeps the running softmax in registers; ``BlockSpec``
expresses the same schedule declaratively.

Lowered with ``interpret=True`` like every kernel in this package, so it
becomes plain HLO the CPU PJRT plugin (and the Rust runtime) can run.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, t_real: int):
    """One (BLK_Q, Dh) output tile for one head.

    q_ref: (BLK_Q, Dh) query tile.
    k_ref: (T_pad, Dh) the head's full key panel.
    v_ref: (T_pad, Dh) the head's full value panel.
    o_ref: (BLK_Q, Dh) output tile.
    """
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    scores = q @ k.T * scale  # (BLK_Q, T_pad) on the MXU
    # Mask padding keys before the softmax (padded rows are zeros, which
    # would otherwise soak up probability mass).
    t_pad = k.shape[0]
    if t_pad != t_real:
        col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        scores = jnp.where(col < t_real, scores, -jnp.inf)
    # Numerically stable softmax, in-register.
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = (p @ v).astype(o_ref.dtype)  # MXU again


@functools.partial(jax.jit, static_argnames=("block_q",))
def fused_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    block_q: int = 16,
) -> jnp.ndarray:
    """Multi-head scaled-dot-product attention, fused per query tile.

    Args:
      q, k, v: (H, T, Dh) float32 per-head projections.
      block_q: query rows per grid step (VMEM tile height).
    Returns:
      (H, T, Dh) attention output, numerically equal (up to f32
      associativity) to ``softmax(q @ k.T / sqrt(Dh)) @ v`` per head.
    """
    h, t, dh = q.shape
    assert k.shape == (h, t, dh) and v.shape == (h, t, dh)
    scale = 1.0 / math.sqrt(dh)

    t_pad = _ceil_to(t, block_q)
    if t_pad != t:
        pad = [(0, 0), (0, t_pad - t), (0, 0)]
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)

    grid = (h, t_pad // block_q)
    out = pl.pallas_call(
        functools.partial(_attention_kernel, scale=scale, t_real=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, dh), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((None, t_pad, dh), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((None, t_pad, dh), lambda hi, qi: (hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, dh), lambda hi, qi: (hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t_pad, dh), q.dtype),
        interpret=True,
    )(q, k, v)
    return out[:, :t, :]
