"""AOT compiler: lower every (model, batch size) pair to an HLO-text artifact.

This is the ONLY step that runs Python. It produces a Triton-style model
repository under ``artifacts/``::

    artifacts/
      particlenet/
        config.yaml          # model metadata the Rust repository parses
        model.b1.hlo.txt     # HLO text, weights baked in, batch size 1
        model.b4.hlo.txt
        ...
        golden.b1.txt        # deterministic input/output pair for numerics
                             #   verification on the Rust side
      icecube_cnn/ ...
      cms_transformer/ ...

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Weights are baked into the HLO as constants (closure at lower time), so a
served artifact is self-contained, like a model version directory in a
Triton repository.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as models

#: batch sizes compiled per model; the Rust dynamic batcher pads requests to
#: the smallest compiled batch >= the accumulated batch.
BATCH_SIZES = (1, 2, 4, 8, 16)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring).

    ``print_large_constants=True`` is load-bearing: the default HLO printer
    elides big weight constants as ``{...}``, which the 0.5.1 text parser
    silently accepts and fills with garbage — the artifact would load and
    run but produce wrong numerics (caught by the golden check).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax >= 0.8 prints source_end_line/... metadata attributes the 0.5.1
    # parser rejects; metadata is debug-only, drop it.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def _write_tensor(f, name: str, arr) -> None:
    import numpy as np

    arr = np.asarray(arr)
    dims = " ".join(str(d) for d in arr.shape)
    f.write(f"{name} {dims}\n")
    flat = arr.reshape(-1)
    f.write(" ".join(f"{v:.8e}" for v in flat.tolist()))
    f.write("\n")


def compile_model(name: str, outdir: str, batch_sizes=BATCH_SIZES) -> dict:
    """Lower one model at every batch size; write artifacts + goldens."""
    spec = models.MODELS[name]
    params = spec["init"](jax.random.PRNGKey(spec["seed"]))
    apply_fn = spec["apply"]
    in_shape = spec["input_shape"]

    mdir = os.path.join(outdir, name)
    os.makedirs(mdir, exist_ok=True)

    fwd = lambda x: (apply_fn(params, x),)

    for bs in batch_sizes:
        x_spec = jax.ShapeDtypeStruct((bs, *in_shape), jnp.float32)
        lowered = jax.jit(fwd).lower(x_spec)
        text = to_hlo_text(lowered)
        path = os.path.join(mdir, f"model.b{bs}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)

        # Deterministic golden pair for Rust-side numerics verification.
        key = jax.random.PRNGKey(1000 + bs)
        x = jax.random.normal(key, (bs, *in_shape), jnp.float32)
        y = fwd(x)[0]
        with open(os.path.join(mdir, f"golden.b{bs}.txt"), "w") as f:
            _write_tensor(f, "input", x)
            _write_tensor(f, "output", y)
        print(f"  {name} b{bs}: {len(text)} chars hlo")

    n_params = models.param_count(params)
    in_dims = " ".join(str(d) for d in in_shape)
    cfg = "\n".join(
        [
            f"name: {name}",
            "platform: jax_pjrt",
            f"parameters: {n_params}",
            "input:",
            "  name: x",
            "  dtype: f32",
            f"  dims: [{', '.join(str(d) for d in in_shape)}]",
            "output:",
            "  name: logits",
            "  dtype: f32",
            f"  dims: [{spec['output_dim']}]",
            f"batch_sizes: [{', '.join(str(b) for b in batch_sizes)}]",
            f"max_batch_size: {max(batch_sizes)}",
            "",
        ]
    )
    with open(os.path.join(mdir, "config.yaml"), "w") as f:
        f.write(cfg)
    return {"name": name, "params": n_params, "input_dims": in_dims}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--models",
        default=",".join(models.MODELS),
        help="comma-separated subset of models to compile",
    )
    ap.add_argument(
        "--batch-sizes",
        default=",".join(str(b) for b in BATCH_SIZES),
        help="comma-separated batch sizes",
    )
    args = ap.parse_args()
    batch_sizes = tuple(int(b) for b in args.batch_sizes.split(","))

    os.makedirs(args.out, exist_ok=True)
    infos = []
    for name in args.models.split(","):
        print(f"compiling {name} ...")
        infos.append(compile_model(name, args.out, batch_sizes))
    with open(os.path.join(args.out, "MANIFEST"), "w") as f:
        for info in infos:
            f.write(f"{info['name']} params={info['params']}\n")
    print("done:", ", ".join(i["name"] for i in infos))


if __name__ == "__main__":
    main()
